"""Multi-device distribution tests.

These need XLA_FLAGS=--xla_force_host_platform_device_count=8, which must be
set before jax initializes — so each case runs tests/_dist_prog.py in a
subprocess (the main pytest process keeps its single-device view, per the
project rule of never forcing device counts globally)."""
import os
import subprocess
import sys

import jax
import pytest

_PROG = os.path.join(os.path.dirname(__file__), "_dist_prog.py")

# The trainer's nested partial-manual shard_map (manual data axes, auto
# model axis, GSPMD constraints inside) needs the modern jax.shard_map /
# XLA; the legacy experimental API's SPMD partitioner aborts with
# "Check failed: sharding.IsManualSubgroup()". The fully-manual oracle
# case runs everywhere.
_legacy_jax = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="nested partial-manual shard_map requires modern jax/XLA")


def _run(case: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    proc = subprocess.run([sys.executable, _PROG, case],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    if proc.returncode != 0:
        raise AssertionError(
            f"{case} failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n"
            f"{proc.stderr[-3000:]}")
    assert "OK" in proc.stdout


@pytest.mark.parametrize("case", [
    pytest.param("dense", marks=_legacy_jax),
    "oracle",
    pytest.param("variants", marks=_legacy_jax),
    pytest.param("multipod", marks=_legacy_jax),
])
def test_distributed(case):
    _run(case)
