"""Multi-device distribution tests.

These need XLA_FLAGS=--xla_force_host_platform_device_count=8, which must
be set before jax initializes — so each case runs tests/_dist_prog.py in a
subprocess through the shared ``run_prog`` fixture (tests/conftest.py)."""
import os

import jax
import pytest

_PROG = os.path.join(os.path.dirname(__file__), "_dist_prog.py")

# The trainer's nested partial-manual shard_map (manual data axes, auto
# model axis, GSPMD constraints inside) needs the modern jax.shard_map /
# XLA; the legacy experimental API's SPMD partitioner aborts with
# "Check failed: sharding.IsManualSubgroup()". The fully-manual oracle
# case runs everywhere.
_legacy_jax = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="nested partial-manual shard_map requires modern jax/XLA")


@pytest.mark.parametrize("case", [
    pytest.param("dense", marks=_legacy_jax),
    "oracle",
    pytest.param("variants", marks=_legacy_jax),
    pytest.param("multipod", marks=_legacy_jax),
])
def test_distributed(case, run_prog):
    run_prog(_PROG, case)
