"""Tier-2: convergence parity on the 8-way simulated cluster.

The accuracy claim RedSync inherits from DGC (Lin et al. 1712.01887),
validated END-TO-END per Agarwal et al. 2103.00543: with momentum
correction, factor masking, local clipping, and a warm-up, aggressively
sparsified training matches dense training — not per-kernel, but as a
real multi-worker run. Each case trains ≥200 steps through
``train.trainer.Trainer`` on an 8-device ``("data",)`` mesh (every worker
compresses its OWN local gradient) and compares held-out loss against the
dense-``psum`` baseline on the identical budget — the baseline gets the
same DGC local clipping, so the measurement isolates sparsification.

The 5%-gated cases use RedSync's OWN §5.7 warm-up (dense-allreduce stages
before the target sparsity — the paper's improvement over DGC's density
ramp); the DGC density ramp is exercised under the looser half-progress
bar the paper's Tab 1 analogue (benchmarks/tab1_convergence.py) also uses.

Slow (minutes per case): marked ``tier2``, skipped unless ``--run-tier2``
/ ``RUN_TIER2=1`` (CI runs these in their own job, modern-jax leg only —
though the harness's fully-manual mesh path also runs on legacy jax).
"""
import pytest

from harness import convergence_pair, run_cluster

STEPS = 200
DEVICES = 8
TOLERANCE = 0.05          # final loss within 5% of dense
INIT_LOSS = 6.24          # ln(512): the bigram task's starting loss

# the paper's own evaluation LSTM + the small transformer
ARCHS = ["paper-lstm", "internlm2-1.8b"]


@pytest.mark.tier2
@pytest.mark.parametrize("arch", ARCHS)
def test_corrected_sparse_matches_dense(arch):
    """momentum+clip(threshold_bsearch) with §5.7 warm-up vs dense psum:
    held-out loss within 5% on both the paper LSTM and the transformer."""
    out = convergence_pair(
        arch, steps=STEPS, devices=DEVICES,
        sparse_optimizer="momentum+clip(threshold_bsearch)",
        density=0.01, warmup_steps_per_stage=25, dense_warmup=True,
        lr=0.1, momentum=0.9, local_clip=1.0)
    dense, sparse = out["dense_loss"], out["sparse_loss"]

    # both runs must have actually learned
    assert dense < INIT_LOSS - 0.5, f"dense baseline did not learn: {dense}"
    assert sparse < INIT_LOSS - 0.5, f"sparse run did not learn: {sparse}"
    # the parity claim: corrected sparse within 5% of dense
    assert sparse <= dense * (1 + TOLERANCE), (
        f"{arch}: sparse {sparse:.4f} vs dense {dense:.4f} "
        f"(+{(sparse / dense - 1) * 100:.1f}%, tolerance "
        f"{TOLERANCE * 100:.0f}%)")


@pytest.mark.tier2
def test_stale1_matches_sequential_sparse():
    """The §5.6 ``stale1`` schedule (communicate step t-1's compressed
    residual during step t — maximal backprop/comm overlap, one step of
    sparse staleness) with the full DGC pipeline + §5.7 dense warm-up:
    its held-out loss must land within 5% of the SAME sparse pipeline
    run sequentially — the staleness cost the overlap is bought with,
    measured end-to-end on the 8-way simulated cluster."""
    common = dict(arch="paper-lstm", steps=STEPS,
                  optimizer="momentum+clip(threshold_bsearch)",
                  density=0.01, warmup_steps_per_stage=25,
                  dense_warmup=True, lr=0.1, momentum=0.9,
                  local_clip=1.0, seed=0)
    seq = run_cluster(dict(common, schedule="sequential"), devices=DEVICES)
    stale = run_cluster(dict(common, schedule="stale1"), devices=DEVICES)
    seq_loss, stale_loss = seq["held_loss"], stale["held_loss"]

    assert seq_loss < INIT_LOSS - 0.5, \
        f"sequential-sparse run did not learn: {seq_loss}"
    assert stale_loss < INIT_LOSS - 0.5, \
        f"stale1 run did not learn: {stale_loss}"
    assert stale_loss <= seq_loss * (1 + TOLERANCE), (
        f"stale1 {stale_loss:.4f} vs sequential-sparse {seq_loss:.4f} "
        f"(+{(stale_loss / seq_loss - 1) * 100:.1f}%, tolerance "
        f"{TOLERANCE * 100:.0f}%)")


@pytest.mark.tier2
def test_dgc_density_ramp_learns():
    """The DGC density ramp (25% → 0.4% stages, no dense phase) on the
    paper's LSTM: must make at least half the dense progress from init —
    the ramp's high-sparsity stages slow early optimization, which is
    exactly why §5.7 recommends the dense warm-up gated above."""
    out = convergence_pair(
        "paper-lstm", steps=STEPS, devices=DEVICES,
        sparse_optimizer="momentum+clip(threshold_bsearch)",
        density=0.01, warmup_steps_per_stage=25, dense_warmup=False,
        lr=0.1, momentum=0.9, local_clip=1.0)
    dense, sparse = out["dense_loss"], out["sparse_loss"]
    assert (INIT_LOSS - sparse) > 0.5 * (INIT_LOSS - dense), (
        f"ramp run lagging: sparse {sparse:.4f} vs dense {dense:.4f}")
