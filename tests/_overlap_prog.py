"""Multi-device overlap-schedule differential program, run as a subprocess
by tests/test_overlap.py with 8 forced host devices (the XLA flag must be
set before jax init, so it cannot run inside the main pytest process).

The §5.6 ``chunked`` schedule's contract: pipelined per-chunk dispatch
changes ONLY the number/order of transport collectives — params and
optimizer state stay BITWISE identical (equal sha256 digests) to the
``sequential`` full-tree-barrier schedule, for every registered sparse
transport, with the flat arenas on AND off, under jit, when every worker
compresses a different local gradient:

  * ``fused``        — fused_allgather on the ("data",)=8 mesh;
  * ``bucketed``     — bucketed_allgather (chunks feeding bucket
                       assignment) on the ("data",)=8 mesh;
  * ``per_leaf``     — per_leaf_allgather on the ("data",)=8 mesh;
  * ``hierarchical`` — the two-level transport on the ("node","local")
                       2x4 mesh (inter-node sparse hop + intra psum);
  * ``corrections``  — fused transport + the full DGC pipeline
                       ("momentum+clip(threshold_bsearch)");
  * ``stale1``       — the one-step-delayed schedule vs an explicitly
                       delayed sequential reference: running sequential
                       on the SAME grads and applying each step's
                       gathered messages one step late must reproduce
                       stale1's params bitwise (8 workers).

Chunk budget is set small relative to the tree so every case really
splits into >= 2 chunks (asserted via a WallClockTimer collective count
in the in-process tests; here the byte budget math is deterministic).
"""
import hashlib
import sys

from harness.cluster import check, force_host_devices

force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import build_gradient_sync
from repro.jaxcompat import shard_map as shard_map_compat
from repro.launch.mesh import _make_mesh

STEPS = 3
LR = 0.1

# mixed §5.5 classes, non-block-multiple sizes; small enough to keep the
# 8-device jit compiles fast, large enough to split into several chunks
TREE_SIZES = {"big": (1 << 18) + 17, "mid": 96 * 1024 + 3,
              "mid2": 33_001, "small": 1_000}
CHUNK_BYTES = 260_000      # several chunks over TREE_SIZES' f32 bytes


def digest(tree) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(tree):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def make_mesh(transport):
    if transport == "hierarchical":
        return _make_mesh((2, 4), ("node", "local")), ("node", "local")
    return _make_mesh((8,), ("data",)), ("data",)


def make_tree(seed=0):
    rng = np.random.default_rng(seed)
    params = {k: jnp.asarray(rng.standard_normal(n), jnp.float32)
              for k, n in TREE_SIZES.items()}
    grads = {k: jnp.asarray(rng.standard_normal((8, STEPS, n)) * 0.01,
                            jnp.float32)
             for k, n in TREE_SIZES.items()}
    return params, grads


def run_steps(schedule, transport, fuse, optimizer="rgc", **kw):
    mesh, axes = make_mesh(transport)
    params, grads = make_tree()

    sync = build_gradient_sync(
        optimizer, transport=transport, sync_axes=axes, density=0.01,
        momentum=0.9, fuse_leaves=fuse, schedule=schedule,
        bucket_bytes=CHUNK_BYTES, **kw)
    state0 = sync.init(params)

    def worker(gs, p, st):
        for t in range(STEPS):
            g_t = {k: g[0, t] for k, g in gs.items()}
            p, st = sync.update(g_t, st, p, jnp.float32(LR))
        return p, st

    f = jax.jit(shard_map_compat(
        worker, mesh=mesh,
        in_specs=({k: P(axes) for k in TREE_SIZES}, P(),
                  jax.tree.map(lambda _: P(), state0)),
        out_specs=(P(), jax.tree.map(lambda _: P(), state0)),
        check_vma=False))
    p2, st2 = f(grads, params, state0)
    return (jax.tree.map(np.asarray, p2), jax.tree.map(np.asarray, st2))


def check_bitwise(name, got, want):
    leaves_g = jax.tree.leaves(got)
    leaves_w = jax.tree.leaves(want)
    same = (len(leaves_g) == len(leaves_w)
            and all(a.dtype == b.dtype
                    and np.array_equal(a, b, equal_nan=True)
                    for a, b in zip(leaves_g, leaves_w)))
    if not same:
        for a, b in zip(leaves_g, leaves_w):
            if not np.array_equal(a, b, equal_nan=True):
                print(f"  mismatch: max|d|="
                      f"{np.max(np.abs(a.astype(np.float64) - b)):.3e}")
    check(name, same)


def diff_case(transport, optimizer="rgc", **kw):
    """chunked == sequential: params + state + digests, fuse on and off."""
    for fuse in (False, True):
        ref_p, ref_s = run_steps("sequential", transport, fuse,
                                 optimizer=optimizer, **kw)
        got_p, got_s = run_steps("chunked", transport, fuse,
                                 optimizer=optimizer, **kw)
        tag = f"{transport} fuse={fuse}"
        check_bitwise(f"chunked == sequential params ({tag})", got_p, ref_p)
        check_bitwise(f"chunked == sequential state ({tag})", got_s, ref_s)
        check(f"chunked == sequential digest ({tag})",
              digest((got_p, got_s)) == digest((ref_p, ref_s)))


def test_fused():
    diff_case("fused_allgather")


def test_bucketed():
    diff_case("bucketed_allgather")


def test_per_leaf():
    diff_case("per_leaf_allgather")


def test_hierarchical():
    diff_case("hierarchical")


def test_corrections():
    diff_case("fused_allgather",
              optimizer="momentum+clip(threshold_bsearch)", local_clip=1.0)


def test_stale1():
    """stale1 == sequential-with-explicitly-delayed-apply, 8 workers.

    The reference re-runs the SEQUENTIAL pipeline but holds each step's
    packed messages for one step: at step t it applies the messages
    packed at t-1 (zero-count at t=0). That is exactly the double-buffer
    semantics ``Stale1Schedule`` implements inside one update, so params
    AND residual state must match bitwise.
    """
    mesh, axes = make_mesh("fused_allgather")
    params, grads = make_tree()

    got_p, got_s = run_steps("stale1", "fused_allgather", True)

    # reference: a sequential sync whose transport dispatch is delayed
    # by hand — compress with the REAL pipeline, but gather/apply the
    # previous step's buffer
    sync = build_gradient_sync(
        "rgc", transport="fused_allgather", sync_axes=axes, density=0.01,
        momentum=0.9, fuse_leaves=True, schedule="sequential",
        bucket_bytes=CHUNK_BYTES)
    state0 = sync.init(params)
    pending0 = sync._pending_zeros(params)

    def worker(gs, p, st):
        pending = list(pending0)
        for t in range(STEPS):
            g_t = {k: g[0, t] for k, g in gs.items()}
            (treedef, leaves_raw, leaves_g, leaves_p, leaves_s,
             n_workers) = sync._context(g_t, st, p)
            plan = sync._plan(g_t, treedef, leaves_raw, sync.density,
                              False)
            new_states = list(leaves_s)
            new_params = list(leaves_p)
            messages, meta = sync._compress_plan(
                plan, leaves_g, leaves_p, leaves_s, new_states)
            gathered = sync._gather(pending)           # one step late
            sync._apply_gathered(gathered, meta, leaves_p, new_params,
                                 jnp.float32(LR), n_workers)
            for i in plan.dense:
                g_mean = sync._dense_reduce(i, leaves_g)
                sync._dense_apply(i, g_mean, leaves_p, leaves_s,
                                  new_states, new_params, jnp.float32(LR))
            pending = messages
            p = jax.tree.unflatten(treedef, new_params)
            st = jax.tree.unflatten(treedef, new_states)
        return p, st

    f = jax.jit(shard_map_compat(
        worker, mesh=mesh,
        in_specs=({k: P(axes) for k in TREE_SIZES}, P(),
                  jax.tree.map(lambda _: P(), state0)),
        out_specs=(P(), jax.tree.map(lambda _: P(), state0)),
        check_vma=False))
    ref_p, ref_s = f(grads, params, state0)
    ref_p = jax.tree.map(np.asarray, ref_p)
    ref_s = jax.tree.map(np.asarray, ref_s)

    check_bitwise("stale1 params == delayed-sequential reference (8 dev)",
                  got_p, ref_p)
    check_bitwise("stale1 leaf state == delayed-sequential reference",
                  got_s.leaf, ref_s)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    fns = {"fused": test_fused,
           "bucketed": test_bucketed,
           "per_leaf": test_per_leaf,
           "hierarchical": test_hierarchical,
           "corrections": test_corrections,
           "stale1": test_stale1}
    if which == "all":
        for fn in fns.values():
            fn()
    else:
        fns[which]()
    print("OK")
