"""Boundary pinning for the §5.5 dispatch (cost_model.choose_method and
SizeBasedPolicy must agree, including AT the 128 KB / 4 MB boundaries and
for degenerate 0-byte leaves)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.cost_model import (DENSE_THRESHOLD_BYTES,
                                   TRIMMED_THRESHOLD_BYTES, choose_method)
from repro.core.dispatch import SizeBasedPolicy, _METHOD_COMPRESSOR


def _leaf(nbytes: int, dtype=jnp.int8) -> jax.ShapeDtypeStruct:
    assert nbytes % jnp.dtype(dtype).itemsize == 0
    return jax.ShapeDtypeStruct((nbytes // jnp.dtype(dtype).itemsize,),
                                dtype)


class TestChooseMethodBoundaries:
    """The boundaries are PINNED half-open: [0,128K) dense, [128K,4M)
    trimmed, [4M,inf) bsearch — "smaller than 128 KB" means exactly 128 KB
    is already sparsified."""

    @pytest.mark.parametrize("nbytes,expect", [
        (0, "dense"),                                   # 0-byte leaf
        (1, "dense"),
        (DENSE_THRESHOLD_BYTES - 1, "dense"),
        (DENSE_THRESHOLD_BYTES, "trimmed_topk"),        # exactly 128 KB
        (DENSE_THRESHOLD_BYTES + 1, "trimmed_topk"),
        (TRIMMED_THRESHOLD_BYTES - 1, "trimmed_topk"),
        (TRIMMED_THRESHOLD_BYTES, "threshold_binary_search"),  # exactly 4 MB
        (TRIMMED_THRESHOLD_BYTES + 1, "threshold_binary_search"),
    ])
    def test_pinned(self, nbytes, expect):
        assert choose_method(nbytes) == expect

    def test_negative_bytes_raise(self):
        with pytest.raises(ValueError):
            choose_method(-1)

    def test_custom_thresholds_stay_half_open(self):
        assert choose_method(1024, 1024, 4096) == "trimmed_topk"
        assert choose_method(4096, 1024, 4096) == "threshold_binary_search"
        assert choose_method(1023, 1024, 4096) == "dense"


class TestSizeBasedPolicyAgreesWithCostModel:
    def test_boundary_leaves(self):
        """Real leaves landing EXACTLY on the boundaries (via dtype choice:
        32768 f32 = 128 KB; 2M bf16 = 4 MB)."""
        pol = SizeBasedPolicy()
        exactly_128k = jax.ShapeDtypeStruct((32 * 1024,), jnp.float32)
        exactly_4m = jax.ShapeDtypeStruct((2 * 1024 * 1024,), jnp.bfloat16)
        assert pol.compressor_for("", exactly_128k) == "trimmed_topk"
        assert pol.compressor_for("", exactly_4m) == "threshold_bsearch"

    def test_zero_size_leaf_is_dense(self):
        pol = SizeBasedPolicy()
        assert pol.compressor_for("", jnp.zeros((0,), jnp.float32)) == "dense"

    @pytest.mark.parametrize("nbytes", [
        0, 1, 64, DENSE_THRESHOLD_BYTES - 1, DENSE_THRESHOLD_BYTES,
        DENSE_THRESHOLD_BYTES + 1, 1024 * 1024, TRIMMED_THRESHOLD_BYTES - 1,
        TRIMMED_THRESHOLD_BYTES, TRIMMED_THRESHOLD_BYTES + 1,
        64 * 1024 * 1024])
    def test_delegation_consistency(self, nbytes):
        """SizeBasedPolicy is exactly choose_method ∘ leaf_nbytes."""
        pol = SizeBasedPolicy()
        assert pol.compressor_for("", _leaf(nbytes)) == \
            _METHOD_COMPRESSOR[choose_method(nbytes)]


class TestSampledSelectKnobs:
    """The sampled-bsearch sizing helpers (§ the DGC-style estimator)."""

    def test_tolerance_zero_or_negative_pins_exact(self):
        from repro.core.cost_model import sample_stride, sampled_capacity
        assert sample_stride(1000, 0.0) == 1
        assert sample_stride(1000, -1.0) == 1
        assert sampled_capacity(64, 0.0) == 128      # exactly 2k

    def test_stride_power_of_two_and_capped(self):
        from repro.core.cost_model import sample_stride
        for k in (16, 100, 4096, 10 ** 6):
            for tol in (0.1, 0.25, 0.5, 1.0):
                s = sample_stride(k, tol)
                assert s >= 1 and (s & (s - 1)) == 0, \
                    f"stride {s} not a power of two"
                assert s <= 1024                      # block cap
        # the cap engages: a huge k at tol=1 wants k/4 but gets 1024
        assert sample_stride(10 ** 7, 1.0) == 1024

    def test_stride_monotone_in_tolerance(self):
        from repro.core.cost_model import sample_stride
        k = 4096
        strides = [sample_stride(k, t) for t in (0.1, 0.2, 0.4, 0.8)]
        assert strides == sorted(strides)

    def test_capacity_headroom_formula(self):
        from repro.core.cost_model import sampled_capacity
        assert sampled_capacity(100, 0.5) == 200 + 100
        assert sampled_capacity(7, 0.5) == 14 + 7
        # ceil rounds partial headroom UP (never undersizes the wire)
        assert sampled_capacity(3, 0.1) == 6 + 1

    def test_sampled_cost_below_exact_cost(self):
        from repro.core.cost_model import t_select_sampled
        m, density = 10 ** 7, 0.001
        exact = t_select_sampled(m, density, 0.0)
        sampled = t_select_sampled(m, density, 0.5)
        assert sampled < exact
