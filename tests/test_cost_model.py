"""Boundary pinning for the §5.5 dispatch (cost_model.choose_method and
SizeBasedPolicy must agree, including AT the 128 KB / 4 MB boundaries and
for degenerate 0-byte leaves)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.cost_model import (DENSE_THRESHOLD_BYTES,
                                   TRIMMED_THRESHOLD_BYTES, choose_method)
from repro.core.dispatch import SizeBasedPolicy, _METHOD_COMPRESSOR


def _leaf(nbytes: int, dtype=jnp.int8) -> jax.ShapeDtypeStruct:
    assert nbytes % jnp.dtype(dtype).itemsize == 0
    return jax.ShapeDtypeStruct((nbytes // jnp.dtype(dtype).itemsize,),
                                dtype)


class TestChooseMethodBoundaries:
    """The boundaries are PINNED half-open: [0,128K) dense, [128K,4M)
    trimmed, [4M,inf) bsearch — "smaller than 128 KB" means exactly 128 KB
    is already sparsified."""

    @pytest.mark.parametrize("nbytes,expect", [
        (0, "dense"),                                   # 0-byte leaf
        (1, "dense"),
        (DENSE_THRESHOLD_BYTES - 1, "dense"),
        (DENSE_THRESHOLD_BYTES, "trimmed_topk"),        # exactly 128 KB
        (DENSE_THRESHOLD_BYTES + 1, "trimmed_topk"),
        (TRIMMED_THRESHOLD_BYTES - 1, "trimmed_topk"),
        (TRIMMED_THRESHOLD_BYTES, "threshold_binary_search"),  # exactly 4 MB
        (TRIMMED_THRESHOLD_BYTES + 1, "threshold_binary_search"),
    ])
    def test_pinned(self, nbytes, expect):
        assert choose_method(nbytes) == expect

    def test_negative_bytes_raise(self):
        with pytest.raises(ValueError):
            choose_method(-1)

    def test_custom_thresholds_stay_half_open(self):
        assert choose_method(1024, 1024, 4096) == "trimmed_topk"
        assert choose_method(4096, 1024, 4096) == "threshold_binary_search"
        assert choose_method(1023, 1024, 4096) == "dense"


class TestSizeBasedPolicyAgreesWithCostModel:
    def test_boundary_leaves(self):
        """Real leaves landing EXACTLY on the boundaries (via dtype choice:
        32768 f32 = 128 KB; 2M bf16 = 4 MB)."""
        pol = SizeBasedPolicy()
        exactly_128k = jax.ShapeDtypeStruct((32 * 1024,), jnp.float32)
        exactly_4m = jax.ShapeDtypeStruct((2 * 1024 * 1024,), jnp.bfloat16)
        assert pol.compressor_for("", exactly_128k) == "trimmed_topk"
        assert pol.compressor_for("", exactly_4m) == "threshold_bsearch"

    def test_zero_size_leaf_is_dense(self):
        pol = SizeBasedPolicy()
        assert pol.compressor_for("", jnp.zeros((0,), jnp.float32)) == "dense"

    @pytest.mark.parametrize("nbytes", [
        0, 1, 64, DENSE_THRESHOLD_BYTES - 1, DENSE_THRESHOLD_BYTES,
        DENSE_THRESHOLD_BYTES + 1, 1024 * 1024, TRIMMED_THRESHOLD_BYTES - 1,
        TRIMMED_THRESHOLD_BYTES, TRIMMED_THRESHOLD_BYTES + 1,
        64 * 1024 * 1024])
    def test_delegation_consistency(self, nbytes):
        """SizeBasedPolicy is exactly choose_method ∘ leaf_nbytes."""
        pol = SizeBasedPolicy()
        assert pol.compressor_for("", _leaf(nbytes)) == \
            _METHOD_COMPRESSOR[choose_method(nbytes)]
