"""Integration: the dry-run machinery (lower + compile + cost/collective
extraction) on a small host mesh, via subprocess (device-count flag)."""
import os
import subprocess
import sys

import jax
import pytest


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="nested partial-manual shard_map requires modern jax/XLA "
    "(legacy SPMD partitioner aborts on the trainer's mixed "
    "manual/auto pattern)")
def test_dryrun_small_mesh():
    prog = os.path.join(os.path.dirname(__file__), "_dryrun_prog.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    proc = subprocess.run([sys.executable, prog], capture_output=True,
                          text=True, env=env, timeout=900)
    if proc.returncode != 0:
        raise AssertionError(
            f"dryrun small-mesh failed:\n{proc.stdout}\n{proc.stderr[-3000:]}")
    assert "OK" in proc.stdout
