"""Integration: the dry-run machinery (lower + compile + cost/collective
extraction) on a small host mesh, via the shared ``run_prog`` subprocess
fixture (device-count flag must precede jax init)."""
import os

import jax
import pytest


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="nested partial-manual shard_map requires modern jax/XLA "
    "(legacy SPMD partitioner aborts on the trainer's mixed "
    "manual/auto pattern)")
def test_dryrun_small_mesh(run_prog):
    run_prog(os.path.join(os.path.dirname(__file__), "_dryrun_prog.py"))
