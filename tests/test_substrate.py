"""Substrate tests: data pipeline determinism, checkpointing, HLO
collective parsing, cost model numerics."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.core.cost_model import (PIZ_DAINT, TPU_V5E, speedup, t_dense,
                                   t_sparse)
from repro.data import SyntheticLM, bigram_batches
from repro.data.synthetic import bigram_entropy, bigram_transition
from repro.launch.hlo_stats import collective_summary, parse_collectives


class TestData:
    def test_synthetic_deterministic_resume(self):
        a = SyntheticLM(1000, 4, 16, seed=7)
        b = SyntheticLM(1000, 4, 16, seed=7)
        np.testing.assert_array_equal(a.batch_at(5)["tokens"],
                                      b.batch_at(5)["tokens"])
        it = iter(a)
        first = [next(it)["tokens"] for _ in range(3)]
        np.testing.assert_array_equal(first[2], a.batch_at(2)["tokens"])

    def test_tokens_in_range(self):
        s = SyntheticLM(50, 8, 64, seed=0)
        t = s.batch_at(0)["tokens"]
        assert t.min() >= 0 and t.max() < 50

    def test_bigram_learnable_floor(self):
        trans = bigram_transition(64, seed=0)
        h = bigram_entropy(trans)
        assert 0 < h < np.log(64)          # below uniform entropy
        # empirical next-token distribution matches the chain
        it = bigram_batches(64, 16, 256, seed=0)
        toks = next(it)["tokens"]
        assert toks.shape == (16, 256)

    def test_bigram_deterministic(self):
        a = next(iter(bigram_batches(32, 2, 16, seed=3)))["tokens"]
        b = next(iter(bigram_batches(32, 2, 16, seed=3)))["tokens"]
        np.testing.assert_array_equal(a, b)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        save(str(tmp_path), 3, tree)
        assert latest_step(str(tmp_path)) == 3
        out = restore(str(tmp_path), tree)
        np.testing.assert_array_equal(out["a"], tree["a"])
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_shape_mismatch_raises(self, tmp_path):
        save(str(tmp_path), 1, {"w": jnp.ones((3,))})
        with pytest.raises(ValueError):
            restore(str(tmp_path), {"w": jnp.ones((4,))})

    def test_missing_leaf_raises(self, tmp_path):
        save(str(tmp_path), 1, {"w": jnp.ones((3,))})
        with pytest.raises(KeyError):
            restore(str(tmp_path), {"w": jnp.ones((3,)),
                                    "extra": jnp.ones((1,))})

    def test_multiple_steps(self, tmp_path):
        for s in (1, 5, 3):
            save(str(tmp_path), s, {"w": jnp.full((2,), float(s))})
        assert latest_step(str(tmp_path)) == 5
        out = restore(str(tmp_path), {"w": jnp.zeros((2,))})
        np.testing.assert_array_equal(out["w"], [5.0, 5.0])


class TestHloStats:
    SAMPLE = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[32]{0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%add
  %cp = f32[16]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %other = f32[8]{0} add(%a, %b)
"""

    def test_parse(self):
        colls = parse_collectives(self.SAMPLE)
        ops = sorted(c.op for c in colls)
        assert ops == ["all-gather", "all-reduce", "collective-permute",
                       "reduce-scatter"]
        ar = next(c for c in colls if c.op == "all-reduce")
        assert ar.result_bytes == 1024 * 512 * 4
        assert ar.group_size == 4
        assert ar.wire_bytes == int(2 * 3 / 4 * ar.result_bytes)
        ag = next(c for c in colls if c.op == "all-gather")
        assert ag.group_size == 8
        assert ag.result_bytes == 64 * 128 * 2

    def test_summary(self):
        s = collective_summary(self.SAMPLE)
        assert s["total_count"] == 4
        assert s["total_wire_bytes"] > 0
        assert set(s["by_op"]) == {"all-gather", "all-reduce",
                                   "collective-permute", "reduce-scatter"}

    def test_async_start_done_counted_once(self):
        txt = """
  %ags = (f32[8]{0}, f32[32]{0}) all-gather-start(%x), replica_groups={{0,1,2,3}}
  %agd = f32[32]{0} all-gather-done(%ags)
"""
        colls = parse_collectives(txt)
        assert len(colls) == 1


class TestCostModel:
    def test_eq1_eq2_regime(self):
        """Comm-bound nets speed up; the sparse bandwidth term scales with
        (p-1)*M*D (the paper's central observation)."""
        m = 128 * 1024 * 1024 // 4          # 128 MB model (VGG-ish)
        assert speedup(8, m, 0.001, PIZ_DAINT) > 1.0
        # at fixed D, scaling p erodes the advantage (concave speedup)
        s16 = speedup(16, m, 0.001, PIZ_DAINT)
        s1024 = speedup(1024, m, 0.001, PIZ_DAINT)
        assert s1024 < s16

    def test_quantized_halves_bandwidth_term(self):
        m = 16 * 1024 * 1024
        tq = t_sparse(64, m, 0.001, TPU_V5E, quantized=True)
        tf = t_sparse(64, m, 0.001, TPU_V5E, quantized=False)
        assert tq < tf

    def test_dense_indep_of_p_asymptotically(self):
        m = 64 * 1024 * 1024
        d128 = t_dense(128, m, PIZ_DAINT)
        d256 = t_dense(256, m, PIZ_DAINT)
        assert abs(d128 - d256) / d128 < 0.02
