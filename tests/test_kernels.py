"""Pallas kernels vs pure-jnp oracles (ref.py): shape/dtype sweeps,
interpret=True on CPU (TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as sel
from repro.kernels import ops, ref
from repro.kernels.block_stats import abs_sum_max
from repro.kernels.compact import compact_gt
from repro.kernels.threshold_count import count_gt
from repro.kernels.residual_update import residual_update

SHAPES = [(4, 128), (8, 256), (3, 1024), (16, 512), (1, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _x2d(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


class TestBlockStats:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_abs_sum_max(self, shape, dtype):
        x = _x2d(shape, dtype)
        s, m = abs_sum_max(x, interpret=True)
        s_ref, m_ref = ref.abs_sum_max(x)
        np.testing.assert_allclose(s, s_ref, rtol=2e-2 if dtype == jnp.bfloat16
                                   else 1e-5)
        np.testing.assert_allclose(m, m_ref, rtol=1e-6)


class TestCountGt:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("thr", [0.0, 0.5, 1.5, 10.0])
    def test_count(self, shape, thr):
        x = _x2d(shape, jnp.float32, seed=shape[1])
        got = count_gt(x, jnp.float32(thr), interpret=True)
        want = ref.count_gt(x, jnp.float32(thr))
        assert int(got) == int(want)


class TestCompactGt:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_against_oracle(self, shape):
        nb, block = shape
        n = nb * block
        x = _x2d((n,), jnp.float32, seed=n)
        thr = jnp.float32(1.0)
        cap = 32
        vals, idx, counts = compact_gt(x.reshape(nb, block), thr, cap, n,
                                       interpret=True)
        v_ref, i_ref, c_ref = ref.compact_gt(x, thr, block, cap)
        np.testing.assert_array_equal(counts, c_ref)
        np.testing.assert_array_equal(idx, i_ref)
        np.testing.assert_allclose(vals, v_ref)

    def test_partial_final_block(self):
        """n not a multiple of block: padding indices must be == n."""
        n, block, cap = 300, 128, 16
        x = _x2d((n,), jnp.float32, seed=1)
        x2, _ = ops._to2d(x, block)
        vals, idx, counts = compact_gt(x2, jnp.float32(0.8), cap, n,
                                       interpret=True)
        flat = np.asarray(idx).reshape(-1)
        assert np.all((flat < n) | (flat == n))


class TestResidualUpdate:
    @pytest.mark.parametrize("momentum", [0.0, 0.9])
    @pytest.mark.parametrize("nesterov", [False, True])
    @pytest.mark.parametrize("shape", [(256,), (33, 17), (4, 8, 16)])
    def test_fused_update(self, momentum, nesterov, shape):
        rng = np.random.default_rng(3)
        g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        u = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        v = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        u_new, v_new = ops.residual_update(g, u, v, momentum=momentum,
                                           nesterov=nesterov)
        u_ref, v_ref = ref.residual_update(g, u, v, momentum=momentum,
                                           nesterov=nesterov)
        np.testing.assert_allclose(u_new, u_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(v_new, v_ref, rtol=1e-5, atol=1e-6)


class TestGoldenEdgeShapes:
    """All four kernels vs their ref.py oracles on the edge geometry the
    shape sweeps above skip: non-block-multiple lengths, all-zero input,
    all-survivor input, and single-element leaves."""

    # flat length, block — chosen so the final block is partial (300/128),
    # a single element (1/128) or exactly one full block (128/128)
    EDGE = [(300, 128), (1, 128), (127, 128), (129, 128), (128, 128)]

    @staticmethod
    def _flat(n, kind, seed=5):
        if kind == "zeros":
            return jnp.zeros((n,), jnp.float32)
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(n).astype(np.float32)
        if kind == "survivors":
            # every element clears a 0.5 threshold
            x = np.sign(x) * (np.abs(x) + 1.0)
        return jnp.asarray(x)

    @pytest.mark.parametrize("n,block", EDGE)
    @pytest.mark.parametrize("kind", ["normal", "zeros", "survivors"])
    def test_block_stats_golden(self, n, block, kind):
        x = self._flat(n, kind)
        x2d, _ = ops._to2d(x, block)
        s, m = abs_sum_max(x2d, interpret=True)
        s_ref, m_ref = ref.abs_sum_max(x)       # zero padding adds nothing
        np.testing.assert_allclose(s, s_ref, rtol=1e-6)
        np.testing.assert_allclose(m, m_ref, rtol=1e-6)

    @pytest.mark.parametrize("n,block", EDGE)
    @pytest.mark.parametrize("kind", ["normal", "zeros", "survivors"])
    def test_count_gt_golden(self, n, block, kind):
        x = self._flat(n, kind)
        x2d, _ = ops._to2d(x, block)
        for thr in (0.0, 0.5, 100.0):
            got = count_gt(x2d, jnp.float32(thr), interpret=True)
            want = ref.count_gt(x, jnp.float32(thr))
            assert int(got) == int(want), (n, block, kind, thr)
        if kind == "survivors":
            assert int(count_gt(x2d, jnp.float32(0.5), interpret=True)) == n

    @pytest.mark.parametrize("n,block", EDGE)
    @pytest.mark.parametrize("kind", ["normal", "zeros", "survivors"])
    def test_compact_gt_golden(self, n, block, kind):
        """Including bucket overflow: all-survivor input with cap < block
        drops overflow identically in kernel and oracle."""
        x = self._flat(n, kind)
        x2d, _ = ops._to2d(x, block)
        for cap in (8, 32):
            vals, idx, counts = compact_gt(x2d, jnp.float32(0.5), cap, n,
                                           interpret=True)
            v_ref, i_ref, c_ref = ref.compact_gt(x, jnp.float32(0.5),
                                                 block, cap)
            np.testing.assert_array_equal(counts, c_ref)
            np.testing.assert_array_equal(idx, i_ref)
            np.testing.assert_allclose(vals, v_ref)
            # padding contract: indices are in range or == sentinel (n)
            flat = np.asarray(idx).reshape(-1)
            assert np.all((flat < n) | (flat == n))

    @pytest.mark.parametrize("shape", [(1,), (300,), (1, 1), (127,)])
    @pytest.mark.parametrize("kind", ["normal", "zeros"])
    def test_residual_update_golden(self, shape, kind):
        n = int(np.prod(shape))
        g = self._flat(n, kind).reshape(shape)
        rng = np.random.default_rng(9)
        u = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        v = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        for momentum, nesterov in ((0.0, False), (0.9, False), (0.9, True)):
            u_new, v_new = ops.residual_update(g, u, v, momentum=momentum,
                                               nesterov=nesterov)
            u_ref, v_ref = ref.residual_update(g, u, v, momentum=momentum,
                                               nesterov=nesterov)
            np.testing.assert_allclose(u_new, u_ref, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(v_new, v_ref, rtol=1e-5, atol=1e-6)


class TestKernelSelectors:
    """ops.py composite selectors must agree with core/selection.py."""

    @pytest.mark.parametrize("n,k", [(1000, 5), (5000, 13), (20000, 20)])
    def test_trimmed_topk_matches_jnp(self, n, k):
        x = _x2d((n,), jnp.float32, seed=n)
        got = ops.trimmed_topk(x, k)
        want = sel.trimmed_topk(x, k)
        assert set(map(int, got.indices)) == set(map(int, want.indices))
        got_vals = sorted(map(float, got.values))
        want_vals = sorted(map(float, want.values))
        np.testing.assert_allclose(got_vals, want_vals, rtol=1e-6)

    @pytest.mark.parametrize("n,k", [(1000, 5), (8192, 16)])
    def test_bsearch_matches_jnp(self, n, k):
        x = _x2d((n,), jnp.float32, seed=n + 1)
        got, thr_g = ops.threshold_binary_search(x, k)
        want, thr_w = sel.threshold_binary_search(x, k)
        np.testing.assert_allclose(thr_g, thr_w, rtol=1e-5)
        assert int(got.count) == int(want.count)
        c = int(got.count)
        assert (set(map(int, np.asarray(got.indices)[:c]))
                == set(map(int, np.asarray(want.indices)[:c])))

    def test_rgc_pallas_backend_end_to_end(self):
        """rgc_apply(backend='pallas') produces the same update as jnp."""
        from repro.core.rgc import RGCConfig, rgc_apply, rgc_init
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.standard_normal((600, 70)),
                                   jnp.float32)}
        grads = {"w": jnp.asarray(rng.standard_normal((600, 70)),
                                  jnp.float32)}
        outs = {}
        for backend in ("jnp", "pallas"):
            cfg = RGCConfig(density=0.001, sync_axes=(), backend=backend,
                            dense_threshold_bytes=1024)
            state = rgc_init(params, cfg)
            new_p, _ = rgc_apply(grads, params, state, lr=jnp.float32(0.1),
                                 cfg=cfg)
            outs[backend] = np.asarray(new_p["w"])
        np.testing.assert_allclose(outs["jnp"], outs["pallas"], rtol=1e-6)


# ---------------------------------------------------------------------------
# segmented (flat-arena) kernels vs their jnp twins
# ---------------------------------------------------------------------------

def _arena(sizes, seed=0):
    """Block-aligned arena [nb, 1024] + geometry for the given slot sizes."""
    from repro.core import arena as A
    group = A.build_group(
        0, "trimmed_topk", "float32",
        [(i, f"l{i}", n, max(1, n // 100), max(1, n // 100),
          1 + 2 * max(1, n // 100)) for i, n in enumerate(sizes)])
    rng = np.random.default_rng(seed)
    arrs = [jnp.asarray(rng.standard_normal(n), jnp.float32) for n in sizes]
    return A.gather(group, arrs), group.geometry, arrs


SEG_CASES = [
    [1000],                       # single slot
    [1023, 1025, 7],              # non-block-multiple mix
    [2048, 1, 5000],              # single-element slot
    [64, 64, 64, 64],             # several tiny slots
]


class TestSegmentedKernels:
    @pytest.mark.parametrize("sizes", SEG_CASES)
    def test_seg_abs_sum_max(self, sizes):
        from repro.kernels import segmented as kseg
        x2d, geom, arrs = _arena(sizes)
        s, m = kseg.seg_abs_sum_max(x2d, geom.block_seg, geom.n_seg,
                                    interpret=True)
        s_ref, m_ref = ref.seg_abs_sum_max(x2d, geom.block_seg,
                                           geom.block_size, geom.n_seg)
        np.testing.assert_allclose(s, s_ref, rtol=1e-6)
        np.testing.assert_array_equal(m, m_ref)
        # and against the per-leaf selector statistics
        for i, a in enumerate(arrs):
            np.testing.assert_array_equal(m[i], jnp.max(jnp.abs(a)))

    @pytest.mark.parametrize("sizes", SEG_CASES)
    @pytest.mark.parametrize("thr", [0.0, 0.5, 2.0])
    def test_seg_count_gt(self, sizes, thr):
        from repro.kernels import segmented as kseg
        x2d, geom, arrs = _arena(sizes, seed=3)
        thrs = jnp.full((geom.n_seg,), thr, jnp.float32)
        got = kseg.seg_count_gt(x2d, geom.block_seg, thrs, interpret=True)
        want = ref.seg_count_gt(x2d, geom.block_seg, thrs, geom.n_seg)
        np.testing.assert_array_equal(got, want)
        # per-segment counts match the per-leaf count over the slot
        # (identical zero padding on both sides)
        for i, a in enumerate(arrs):
            pad = (-a.size) % 1024
            assert int(got[i]) == int(
                jnp.sum(jnp.abs(jnp.pad(a, (0, pad))) > thr))

    @pytest.mark.parametrize("sizes", SEG_CASES)
    def test_seg_compact_gt(self, sizes):
        from repro.kernels import segmented as kseg
        x2d, geom, arrs = _arena(sizes, seed=7)
        thrs = jnp.full((geom.n_seg,), 0.8, jnp.float32)
        cap = 16
        g = kseg.seg_compact_gt(x2d, geom.block_seg, geom.block_base,
                                geom.block_size, thrs, cap, interpret=True)
        w = ref.seg_compact_gt(x2d, geom.block_seg, geom.block_base,
                               geom.block_size, thrs, cap)
        np.testing.assert_array_equal(g[2], w[2])     # counts
        np.testing.assert_array_equal(g[1], w[1])     # local indices
        np.testing.assert_allclose(g[0], w[0])        # values
        # indices are slot-LOCAL with padding == slot size; padding in
        # the arena (beyond each slot's size) is never selected
        for s_ord, (r0, r1) in enumerate(geom.seg_rows):
            size = geom.seg_sizes[s_ord]
            idx = np.asarray(g[1][r0:r1])
            assert np.all(idx <= size)

    @pytest.mark.parametrize("momentum,nesterov,wd",
                             [(0.9, False, 0.0), (0.9, True, 0.0),
                              (0.0, False, 0.0), (0.9, False, 0.01)])
    def test_seg_residual_update_stats(self, momentum, nesterov, wd):
        from repro.kernels import segmented as kseg
        sizes = [1023, 300, 2048]
        x2d, geom, _ = _arena(sizes, seed=9)
        g2d, _, _ = _arena(sizes, seed=10)
        u2d, _, _ = _arena(sizes, seed=11)
        p2d, _, _ = _arena(sizes, seed=12)
        got = kseg.seg_residual_update_stats(
            g2d, x2d, u2d if momentum else None, p2d if wd else None,
            geom.block_seg, geom.n_seg, momentum=momentum,
            nesterov=nesterov, weight_decay=wd, interpret=True)
        want = ref.seg_residual_update_stats(
            g2d, x2d, u2d if momentum else None, p2d if wd else None,
            geom.block_seg, geom.n_seg, momentum=momentum,
            nesterov=nesterov, weight_decay=wd)
        # the fused kernel may FMA-contract the momentum product
        # (documented fuse_accumulate caveat): allow last-ulp noise
        np.testing.assert_allclose(got[0], want[0], rtol=1e-6,
                                   atol=1e-6)              # V'
        if momentum:
            np.testing.assert_allclose(got[1], want[1], rtol=1e-6,
                                       atol=1e-6)          # U'
        else:
            assert got[1] is None and want[1] is None
        np.testing.assert_allclose(got[2], want[2], rtol=1e-5)  # sums
        np.testing.assert_allclose(got[3], want[3], rtol=1e-6)  # maxs

    def test_seg_residual_bf16_round(self):
        from repro.kernels import segmented as kseg
        sizes = [1500]
        x2d, geom, _ = _arena(sizes, seed=20)
        g2d, _, _ = _arena(sizes, seed=21)
        v, _, _, _ = kseg.seg_residual_update_stats(
            g2d, x2d, None, None, geom.block_seg, geom.n_seg,
            momentum=0.0, nesterov=False, round_dtype=jnp.bfloat16,
            interpret=True)
        v = np.asarray(v)
        assert np.array_equal(v, np.asarray(
            jnp.asarray(v).astype(jnp.bfloat16).astype(jnp.float32)))


class TestSegmentedSelectors:
    """Segmented selectors vs the per-leaf selectors, slot by slot
    (the bitwise contract the arena pipeline rests on)."""

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_trimmed_matches_per_leaf(self, use_pallas):
        from repro.core.selection import trimmed_topk
        from repro.kernels import segmented as kseg
        sizes = [33_001, 500, 2048]
        x2d, geom, arrs = _arena(sizes, seed=31)
        selected = kseg.trimmed_topk_segments(
            x2d, geom, use_pallas=use_pallas, interpret=True)
        for i, a in enumerate(arrs):
            k = geom.seg_ks[i]
            if use_pallas:
                want = ops.trimmed_topk(a, k, interpret=True)
            else:
                want = trimmed_topk(a, k)
            np.testing.assert_array_equal(selected[i].indices, want.indices)
            np.testing.assert_array_equal(selected[i].values, want.values)
            assert int(selected[i].count) == int(want.count)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_bsearch_matches_per_leaf(self, use_pallas):
        from repro.core.selection import threshold_binary_search
        from repro.kernels import segmented as kseg
        sizes = [33_001, 4096]
        x2d, geom, arrs = _arena(sizes, seed=32)
        sel_list, thr = kseg.threshold_bsearch_segments(
            x2d, geom, use_pallas=use_pallas, interpret=True)
        for i, a in enumerate(arrs):
            k = geom.seg_ks[i]
            if use_pallas:
                want, thr_want = ops.threshold_binary_search(
                    a, k, interpret=True)
            else:
                want, thr_want = threshold_binary_search(a, k)
            np.testing.assert_array_equal(sel_list[i].indices, want.indices)
            np.testing.assert_array_equal(sel_list[i].values, want.values)
            assert int(sel_list[i].count) == int(want.count)
            np.testing.assert_array_equal(thr[i], thr_want)
