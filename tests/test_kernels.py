"""Pallas kernels vs pure-jnp oracles (ref.py): shape/dtype sweeps,
interpret=True on CPU (TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as sel
from repro.kernels import ops, ref
from repro.kernels.block_stats import abs_sum_max
from repro.kernels.compact import compact_gt
from repro.kernels.threshold_count import count_gt
from repro.kernels.residual_update import residual_update

SHAPES = [(4, 128), (8, 256), (3, 1024), (16, 512), (1, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _x2d(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


class TestBlockStats:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_abs_sum_max(self, shape, dtype):
        x = _x2d(shape, dtype)
        s, m = abs_sum_max(x, interpret=True)
        s_ref, m_ref = ref.abs_sum_max(x)
        np.testing.assert_allclose(s, s_ref, rtol=2e-2 if dtype == jnp.bfloat16
                                   else 1e-5)
        np.testing.assert_allclose(m, m_ref, rtol=1e-6)


class TestCountGt:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("thr", [0.0, 0.5, 1.5, 10.0])
    def test_count(self, shape, thr):
        x = _x2d(shape, jnp.float32, seed=shape[1])
        got = count_gt(x, jnp.float32(thr), interpret=True)
        want = ref.count_gt(x, jnp.float32(thr))
        assert int(got) == int(want)


class TestCompactGt:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_against_oracle(self, shape):
        nb, block = shape
        n = nb * block
        x = _x2d((n,), jnp.float32, seed=n)
        thr = jnp.float32(1.0)
        cap = 32
        vals, idx, counts = compact_gt(x.reshape(nb, block), thr, cap, n,
                                       interpret=True)
        v_ref, i_ref, c_ref = ref.compact_gt(x, thr, block, cap)
        np.testing.assert_array_equal(counts, c_ref)
        np.testing.assert_array_equal(idx, i_ref)
        np.testing.assert_allclose(vals, v_ref)

    def test_partial_final_block(self):
        """n not a multiple of block: padding indices must be == n."""
        n, block, cap = 300, 128, 16
        x = _x2d((n,), jnp.float32, seed=1)
        x2, _ = ops._to2d(x, block)
        vals, idx, counts = compact_gt(x2, jnp.float32(0.8), cap, n,
                                       interpret=True)
        flat = np.asarray(idx).reshape(-1)
        assert np.all((flat < n) | (flat == n))


class TestResidualUpdate:
    @pytest.mark.parametrize("momentum", [0.0, 0.9])
    @pytest.mark.parametrize("nesterov", [False, True])
    @pytest.mark.parametrize("shape", [(256,), (33, 17), (4, 8, 16)])
    def test_fused_update(self, momentum, nesterov, shape):
        rng = np.random.default_rng(3)
        g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        u = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        v = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        u_new, v_new = ops.residual_update(g, u, v, momentum=momentum,
                                           nesterov=nesterov)
        u_ref, v_ref = ref.residual_update(g, u, v, momentum=momentum,
                                           nesterov=nesterov)
        np.testing.assert_allclose(u_new, u_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(v_new, v_ref, rtol=1e-5, atol=1e-6)


class TestGoldenEdgeShapes:
    """All four kernels vs their ref.py oracles on the edge geometry the
    shape sweeps above skip: non-block-multiple lengths, all-zero input,
    all-survivor input, and single-element leaves."""

    # flat length, block — chosen so the final block is partial (300/128),
    # a single element (1/128) or exactly one full block (128/128)
    EDGE = [(300, 128), (1, 128), (127, 128), (129, 128), (128, 128)]

    @staticmethod
    def _flat(n, kind, seed=5):
        if kind == "zeros":
            return jnp.zeros((n,), jnp.float32)
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(n).astype(np.float32)
        if kind == "survivors":
            # every element clears a 0.5 threshold
            x = np.sign(x) * (np.abs(x) + 1.0)
        return jnp.asarray(x)

    @pytest.mark.parametrize("n,block", EDGE)
    @pytest.mark.parametrize("kind", ["normal", "zeros", "survivors"])
    def test_block_stats_golden(self, n, block, kind):
        x = self._flat(n, kind)
        x2d, _ = ops._to2d(x, block)
        s, m = abs_sum_max(x2d, interpret=True)
        s_ref, m_ref = ref.abs_sum_max(x)       # zero padding adds nothing
        np.testing.assert_allclose(s, s_ref, rtol=1e-6)
        np.testing.assert_allclose(m, m_ref, rtol=1e-6)

    @pytest.mark.parametrize("n,block", EDGE)
    @pytest.mark.parametrize("kind", ["normal", "zeros", "survivors"])
    def test_count_gt_golden(self, n, block, kind):
        x = self._flat(n, kind)
        x2d, _ = ops._to2d(x, block)
        for thr in (0.0, 0.5, 100.0):
            got = count_gt(x2d, jnp.float32(thr), interpret=True)
            want = ref.count_gt(x, jnp.float32(thr))
            assert int(got) == int(want), (n, block, kind, thr)
        if kind == "survivors":
            assert int(count_gt(x2d, jnp.float32(0.5), interpret=True)) == n

    @pytest.mark.parametrize("n,block", EDGE)
    @pytest.mark.parametrize("kind", ["normal", "zeros", "survivors"])
    def test_compact_gt_golden(self, n, block, kind):
        """Including bucket overflow: all-survivor input with cap < block
        drops overflow identically in kernel and oracle."""
        x = self._flat(n, kind)
        x2d, _ = ops._to2d(x, block)
        for cap in (8, 32):
            vals, idx, counts = compact_gt(x2d, jnp.float32(0.5), cap, n,
                                           interpret=True)
            v_ref, i_ref, c_ref = ref.compact_gt(x, jnp.float32(0.5),
                                                 block, cap)
            np.testing.assert_array_equal(counts, c_ref)
            np.testing.assert_array_equal(idx, i_ref)
            np.testing.assert_allclose(vals, v_ref)
            # padding contract: indices are in range or == sentinel (n)
            flat = np.asarray(idx).reshape(-1)
            assert np.all((flat < n) | (flat == n))

    @pytest.mark.parametrize("shape", [(1,), (300,), (1, 1), (127,)])
    @pytest.mark.parametrize("kind", ["normal", "zeros"])
    def test_residual_update_golden(self, shape, kind):
        n = int(np.prod(shape))
        g = self._flat(n, kind).reshape(shape)
        rng = np.random.default_rng(9)
        u = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        v = jnp.asarray(rng.standard_normal(shape), jnp.float32)
        for momentum, nesterov in ((0.0, False), (0.9, False), (0.9, True)):
            u_new, v_new = ops.residual_update(g, u, v, momentum=momentum,
                                               nesterov=nesterov)
            u_ref, v_ref = ref.residual_update(g, u, v, momentum=momentum,
                                               nesterov=nesterov)
            np.testing.assert_allclose(u_new, u_ref, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(v_new, v_ref, rtol=1e-5, atol=1e-6)


class TestKernelSelectors:
    """ops.py composite selectors must agree with core/selection.py."""

    @pytest.mark.parametrize("n,k", [(1000, 5), (5000, 13), (20000, 20)])
    def test_trimmed_topk_matches_jnp(self, n, k):
        x = _x2d((n,), jnp.float32, seed=n)
        got = ops.trimmed_topk(x, k)
        want = sel.trimmed_topk(x, k)
        assert set(map(int, got.indices)) == set(map(int, want.indices))
        got_vals = sorted(map(float, got.values))
        want_vals = sorted(map(float, want.values))
        np.testing.assert_allclose(got_vals, want_vals, rtol=1e-6)

    @pytest.mark.parametrize("n,k", [(1000, 5), (8192, 16)])
    def test_bsearch_matches_jnp(self, n, k):
        x = _x2d((n,), jnp.float32, seed=n + 1)
        got, thr_g = ops.threshold_binary_search(x, k)
        want, thr_w = sel.threshold_binary_search(x, k)
        np.testing.assert_allclose(thr_g, thr_w, rtol=1e-5)
        assert int(got.count) == int(want.count)
        c = int(got.count)
        assert (set(map(int, np.asarray(got.indices)[:c]))
                == set(map(int, np.asarray(want.indices)[:c])))

    def test_rgc_pallas_backend_end_to_end(self):
        """rgc_apply(backend='pallas') produces the same update as jnp."""
        from repro.core.rgc import RGCConfig, rgc_apply, rgc_init
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.standard_normal((600, 70)),
                                   jnp.float32)}
        grads = {"w": jnp.asarray(rng.standard_normal((600, 70)),
                                  jnp.float32)}
        outs = {}
        for backend in ("jnp", "pallas"):
            cfg = RGCConfig(density=0.001, sync_axes=(), backend=backend,
                            dense_threshold_bytes=1024)
            state = rgc_init(params, cfg)
            new_p, _ = rgc_apply(grads, params, state, lr=jnp.float32(0.1),
                                 cfg=cfg)
            outs[backend] = np.asarray(new_p["w"])
        np.testing.assert_allclose(outs["jnp"], outs["pallas"], rtol=1e-6)
