"""Cross-architecture model invariants.

* causality: logits at position t do not depend on tokens after t
  (all autoregressive families, incl. SWA / prefix-LM / SSM / hybrid).
* MoE dispatch implementations agree (onehot vs scatter).
* RG-LRU column-parallel gate refactor preserves the recurrence.
* chunked WKV == exact recurrence (rwkv6 chunk algebra).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import get_model


CAUSAL_ARCHS = ["internlm2-1.8b", "gemma3-4b", "h2o-danube-3-4b",
                "rwkv6-3b", "recurrentgemma-9b", "qwen3-32b",
                "granite-moe-3b-a800m"]


@pytest.mark.parametrize("arch", CAUSAL_ARCHS)
def test_causality(arch):
    """Perturbing tokens after position t must not change logits <= t."""
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = m.init_params(0)
    toks = m.make_train_batch(1, 24)["tokens"]
    toks2 = toks.at[:, 12:].set((toks[:, 12:] + 7) % cfg.vocab_size)

    def logits_upto(t, tokens):
        cache = m.init_cache(1, 24)
        _, logits = m.prefill(params, {"tokens": tokens[:, :t]}, cache)
        return logits

    l1 = logits_upto(12, toks)
    l2 = logits_upto(12, toks2)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=1e-5)


def test_moe_impls_agree():
    cfg = get_config("granite-moe-3b-a800m", smoke=True)
    from repro.models import moe
    from repro.models.common import init_params
    p = init_params(moe.moe_defs(cfg), 0, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 24, cfg.d_model)), jnp.float32)
    o1, a1 = moe.moe_ffn(cfg, p, x)
    o2, a2 = moe.moe_ffn(dataclasses.replace(cfg, moe_impl="scatter"), p, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_moe_capacity_drops_gracefully():
    """capacity_factor -> tiny: tokens drop but output stays finite and
    the residual path is preserved (dropped tokens get zero update)."""
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m", smoke=True),
                              moe_capacity_factor=0.1, moe_impl="scatter")
    from repro.models import moe
    from repro.models.common import init_params
    p = init_params(moe.moe_defs(cfg), 0, jnp.float32)
    x = jnp.ones((1, 16, cfg.d_model), jnp.float32)
    out, _ = moe.moe_ffn(cfg, p, x)
    assert np.isfinite(np.asarray(out)).all()


def test_rwkv6_chunked_matches_recurrence():
    """The chunk-parallel WKV must equal the exact per-token recurrence."""
    from repro.models import rwkv6
    from repro.models.common import init_params
    cfg = get_config("rwkv6-3b", smoke=True)
    p = init_params(rwkv6._tm_defs(cfg), 0, jnp.float32)
    rng = np.random.default_rng(0)
    b, s, d = 2, 13, cfg.d_model
    x = jnp.asarray(rng.standard_normal((b, s, d)) * 0.1, jnp.float32)
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    x_prev = jnp.zeros((b, d), jnp.float32)

    out_chunk, _, s_chunk = rwkv6.time_mix(cfg, p, x, x_prev, s0, chunk=4)

    # exact recurrence, one token at a time
    outs = []
    st, xp = s0, x_prev
    for t in range(s):
        o, xp, st = rwkv6.time_mix_decode(cfg, p, x[:, t:t+1], xp, st)
        outs.append(o)
    out_rec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_rec),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(st),
                               atol=1e-4)


def test_rglru_scan_matches_step():
    """Associative-scan LRU == sequential one-step recurrence."""
    from repro.models import rglru
    from repro.models.common import init_params
    cfg = get_config("recurrentgemma-9b", smoke=True)
    p = init_params(rglru.lru_defs(cfg), 0, jnp.float32)
    rng = np.random.default_rng(1)
    b, s, w = 2, 11, cfg.lru_width
    u = jnp.asarray(rng.standard_normal((b, s, w)) * 0.3, jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, w)) * 0.1, jnp.float32)

    hs, h_last = rglru.lru_scan(p, u, h0)
    ht = h0
    for t in range(s):
        out_t, ht = rglru.lru_step(p, u[:, t:t+1], ht)
        np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(ht),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(ht), atol=1e-5)


def test_prefix_lm_bidirectional_within_prefix():
    """paligemma: prefix tokens attend bidirectionally — changing a LATER
    prefix patch changes EARLIER prefix-position outputs (unlike causal),
    while text stays causal w.r.t. text."""
    cfg = get_config("paligemma-3b", smoke=True)
    m = get_model(cfg)
    params = m.init_params(0)
    batch = m.make_train_batch(1, 12)
    from repro.models import transformer
    h1, _, _ = transformer.hidden_states(cfg, params, batch["tokens"],
                                         batch["prefix_embeds"])
    pe2 = batch["prefix_embeds"].at[:, -1].add(1.0)
    h2, _, _ = transformer.hidden_states(cfg, params, batch["tokens"], pe2)
    # position 0 of the prefix must see the change (bidirectional)
    assert float(jnp.max(jnp.abs(h1[:, 0] - h2[:, 0]))) > 1e-6
