"""Unit tests for the communication-set selectors (Algorithms 2/3, §5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as sel


def _vec(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n), jnp.float32)


class TestExactTopK:
    def test_matches_numpy(self):
        x = _vec(1000)
        s = sel.exact_topk(x, 10)
        ref = np.argsort(-np.abs(np.asarray(x)))[:10]
        assert set(map(int, s.indices)) == set(map(int, ref))
        np.testing.assert_allclose(np.asarray(x)[s.indices], s.values)
        assert int(s.count) == 10


class TestTrimmedTopK:
    @pytest.mark.parametrize("n,k", [(100, 5), (1000, 10), (4096, 4),
                                     (10000, 100), (257, 7)])
    def test_selects_exact_topk_set(self, n, k):
        """Alg 2 trims then exact-selects: result == exact top-k set."""
        x = _vec(n, seed=n + k)
        s = sel.trimmed_topk(x, k)
        ref = sel.exact_topk(x, k)
        assert set(map(int, s.indices)) == set(map(int, ref.indices))
        assert int(s.count) == k

    def test_constant_input(self):
        """Degenerate stats (max == mean) must not loop forever."""
        x = jnp.ones(256)
        s = sel.trimmed_topk(x, 3)
        assert int(s.count) == 3
        assert np.all(np.asarray(s.values) == 1.0)


class TestThresholdBinarySearch:
    @pytest.mark.parametrize("n,k", [(1000, 10), (4096, 40), (50000, 50)])
    def test_count_in_band(self, n, k):
        x = _vec(n, seed=n)
        s, thr = sel.threshold_binary_search(x, k)
        cnt = int(s.count)
        # the paper's termination: k <= nnz <= 2k (or search exhausted)
        assert cnt >= 1 and cnt <= 2 * k
        assert s.indices.shape[0] == 2 * k
        # every selected element exceeds the returned threshold
        vals = np.asarray(s.values)[:cnt]
        assert np.all(np.abs(vals) > float(thr))

    def test_selected_superset_of_topk(self):
        """>= k largest elements always included (paper's guarantee
        'at least k largest elements included in the communication-set')."""
        x = _vec(2048, seed=7)
        k = 16
        s, _ = sel.threshold_binary_search(x, k)
        top = set(map(int, sel.exact_topk(x, k).indices))
        got = set(map(int, np.asarray(s.indices)[: int(s.count)]))
        assert top <= got

    def test_threshold_reuse_filter(self):
        x = _vec(1024, seed=3)
        k = 8
        s, thr = sel.threshold_binary_search(x, k)
        s2 = sel.threshold_filter(x, thr, capacity=2 * k)
        assert int(s2.count) == int(s.count)
        assert set(map(int, np.asarray(s2.indices)[: int(s2.count)])) == \
            set(map(int, np.asarray(s.indices)[: int(s.count)]))


class TestQuantized:
    def test_same_sign_phases(self):
        x = _vec(512, seed=1)
        for fn in (sel.exact_topk_quant,
                   lambda x, k, p: sel.trimmed_topk_quant(x, k, p),
                   lambda x, k, p: sel.threshold_binary_search_quant(x, k, p)):
            pos = fn(x, 8, jnp.int32(0))
            neg = fn(x, 8, jnp.int32(1))
            vp = np.asarray(pos.values)[np.asarray(pos.indices) < x.size]
            vn = np.asarray(neg.values)[np.asarray(neg.indices) < x.size]
            assert np.all(vp >= 0), "phase 0 must select positives"
            assert np.all(vn <= 0), "phase 1 must select negatives"

    def test_mean_broadcast(self):
        """Quantized values are the mean of the selected set (§5.2.3)."""
        x = _vec(256, seed=2)
        s = sel.exact_topk_quant(x, 4, jnp.int32(0))
        raw = sel._signed_score(x, jnp.int32(0))
        _, idx = jax.lax.top_k(raw, 4)
        expect = float(jnp.mean(x[idx]))
        got = np.asarray(s.values)[np.asarray(s.indices) < x.size]
        np.testing.assert_allclose(got, expect, rtol=1e-5)


def test_jit_compatible():
    x = _vec(2048)
    f = jax.jit(lambda v: sel.trimmed_topk(v, 8))
    s = f(x)
    assert int(s.count) == 8
    g = jax.jit(lambda v: sel.threshold_binary_search(v, 8))
    s2, thr = g(x)
    assert s2.indices.shape == (16,)
