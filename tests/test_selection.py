"""Unit tests for the communication-set selectors (Algorithms 2/3, §5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as sel


def _vec(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n), jnp.float32)


class TestExactTopK:
    def test_matches_numpy(self):
        x = _vec(1000)
        s = sel.exact_topk(x, 10)
        ref = np.argsort(-np.abs(np.asarray(x)))[:10]
        assert set(map(int, s.indices)) == set(map(int, ref))
        np.testing.assert_allclose(np.asarray(x)[s.indices], s.values)
        assert int(s.count) == 10


class TestTrimmedTopK:
    @pytest.mark.parametrize("n,k", [(100, 5), (1000, 10), (4096, 4),
                                     (10000, 100), (257, 7)])
    def test_selects_exact_topk_set(self, n, k):
        """Alg 2 trims then exact-selects: result == exact top-k set."""
        x = _vec(n, seed=n + k)
        s = sel.trimmed_topk(x, k)
        ref = sel.exact_topk(x, k)
        assert set(map(int, s.indices)) == set(map(int, ref.indices))
        assert int(s.count) == k

    def test_constant_input(self):
        """Degenerate stats (max == mean) must not loop forever."""
        x = jnp.ones(256)
        s = sel.trimmed_topk(x, 3)
        assert int(s.count) == 3
        assert np.all(np.asarray(s.values) == 1.0)


class TestThresholdBinarySearch:
    @pytest.mark.parametrize("n,k", [(1000, 10), (4096, 40), (50000, 50)])
    def test_count_in_band(self, n, k):
        x = _vec(n, seed=n)
        s, thr = sel.threshold_binary_search(x, k)
        cnt = int(s.count)
        # the paper's termination: k <= nnz <= 2k (or search exhausted)
        assert cnt >= 1 and cnt <= 2 * k
        assert s.indices.shape[0] == 2 * k
        # every selected element exceeds the returned threshold
        vals = np.asarray(s.values)[:cnt]
        assert np.all(np.abs(vals) > float(thr))

    def test_selected_superset_of_topk(self):
        """>= k largest elements always included (paper's guarantee
        'at least k largest elements included in the communication-set')."""
        x = _vec(2048, seed=7)
        k = 16
        s, _ = sel.threshold_binary_search(x, k)
        top = set(map(int, sel.exact_topk(x, k).indices))
        got = set(map(int, np.asarray(s.indices)[: int(s.count)]))
        assert top <= got

    def test_threshold_reuse_filter(self):
        x = _vec(1024, seed=3)
        k = 8
        s, thr = sel.threshold_binary_search(x, k)
        s2 = sel.threshold_filter(x, thr, capacity=2 * k)
        assert int(s2.count) == int(s.count)
        assert set(map(int, np.asarray(s2.indices)[: int(s2.count)])) == \
            set(map(int, np.asarray(s.indices)[: int(s.count)]))


class TestQuantized:
    def test_same_sign_phases(self):
        x = _vec(512, seed=1)
        for fn in (sel.exact_topk_quant,
                   lambda x, k, p: sel.trimmed_topk_quant(x, k, p),
                   lambda x, k, p: sel.threshold_binary_search_quant(x, k, p)):
            pos = fn(x, 8, jnp.int32(0))
            neg = fn(x, 8, jnp.int32(1))
            vp = np.asarray(pos.values)[np.asarray(pos.indices) < x.size]
            vn = np.asarray(neg.values)[np.asarray(neg.indices) < x.size]
            assert np.all(vp >= 0), "phase 0 must select positives"
            assert np.all(vn <= 0), "phase 1 must select negatives"

    def test_mean_broadcast(self):
        """Quantized values are the mean of the selected set (§5.2.3)."""
        x = _vec(256, seed=2)
        s = sel.exact_topk_quant(x, 4, jnp.int32(0))
        raw = sel._signed_score(x, jnp.int32(0))
        _, idx = jax.lax.top_k(raw, 4)
        expect = float(jnp.mean(x[idx]))
        got = np.asarray(s.values)[np.asarray(s.indices) < x.size]
        np.testing.assert_allclose(got, expect, rtol=1e-5)

    def test_mean_is_pinned(self):
        """The quantized mean routes through pinned_sum/mean_of_sum, so
        it is BITWISE the pinned computation — not whatever partial-sum
        order jnp.sum picks in a given graph shape."""
        x = _vec(1024, seed=4)
        k, phase = 16, jnp.int32(0)
        s = sel.threshold_binary_search_quant(x, k, phase)
        valid = np.asarray(s.indices) < x.size
        # reconstruct the pinned mean from the selected RAW values
        raw_vals = jnp.where(jnp.asarray(valid),
                             jnp.asarray(x)[jnp.asarray(s.indices) % x.size],
                             0.0)
        total = sel.pinned_sum(raw_vals)
        mean = sel.mean_of_sum(total, jnp.maximum(s.count, 1))
        got = np.asarray(s.values)[valid]
        assert np.all(got == np.float32(mean)), \
            "quantized mean is not the pinned sum/mean computation"

    def test_mean_stable_across_graph_shapes(self):
        """Same selection embedded in different jit graphs must produce
        the identical mean bit pattern (the jnp.sum regression this
        pins: reduce splitting varied with surrounding fusion)."""
        from repro.core.selection import Selected
        x = _vec(2048, seed=5)
        k, phase = 8, jnp.int32(1)

        def plain(v):
            return sel.threshold_binary_search_quant(v, k, phase)

        def fused_context(v):
            s = sel.threshold_binary_search_quant(v * 1.0, k, phase)
            return Selected(s.indices, s.values + 0.0, s.count, s.overflow)

        a = jax.jit(plain)(x)
        b = jax.jit(fused_context)(x)
        np.testing.assert_array_equal(np.asarray(a.values),
                                      np.asarray(b.values))


class TestThresholdShortCircuit:
    """The dead re-search bugfix: a caller-supplied ``threshold=`` must
    short-circuit straight to the filter — no bisection traced at all."""

    def test_no_search_traced_with_threshold(self):
        x = _vec(4096, seed=5)
        jaxpr = jax.make_jaxpr(
            lambda v, t: sel.threshold_binary_search(v, 16, threshold=t)
        )(x, jnp.float32(0.7))
        prims = {e.primitive.name for e in jaxpr.jaxpr.eqns}
        assert "while" not in prims, \
            "threshold= path still traces the bisection loop"
        # and the cold path DOES trace it (the check is meaningful)
        cold = jax.make_jaxpr(
            lambda v: sel.threshold_binary_search(v, 16))(x)
        assert "while" in {e.primitive.name for e in cold.jaxpr.eqns}

    def test_threshold_path_is_the_filter(self):
        x = _vec(2048, seed=6)
        k, thr = 16, jnp.float32(0.9)
        s, t_out = sel.threshold_binary_search(x, k, threshold=thr)
        ref = sel.threshold_filter(x, thr, capacity=2 * k)
        assert float(t_out) == float(thr)
        np.testing.assert_array_equal(np.asarray(s.indices),
                                      np.asarray(ref.indices))
        np.testing.assert_array_equal(np.asarray(s.values),
                                      np.asarray(ref.values))
        assert int(s.count) == int(ref.count)


class TestLadderPinning:
    """Alg 2's ratio ladder is pinned as (integer step x eps): the f32
    running subtraction it replaces accumulates error and leaves a
    spurious near-zero rung at the bottom."""

    def test_final_rung_exactly_zero(self):
        # the bug being pinned: sequential f32 subtraction misses 0.0
        r = np.float32(1.0)
        for _ in range(5):
            r = np.float32(r - np.float32(0.2))
        assert r != np.float32(0.0)
        # the pinned ladder hits it exactly, so the eps=0.2 ladder has
        # exactly 5 rungs — no 6th near-zero iteration
        assert float(sel.ladder_ratio(jnp.int32(5), 0.2)) == 0.0
        assert float(sel.ladder_ratio(jnp.int32(4), 0.2)) > 0.0

    def test_first_rung_value_unchanged(self):
        # rung 1 must stay bitwise what the old `1 - eps` init computed
        assert np.float32(sel.ladder_ratio(jnp.int32(1), 0.2)) == \
            np.float32(1.0) - np.float32(0.2)

    def test_ladder_exhaustion_still_selects_k(self):
        # nnz(|x| > mean) < k forces the walk to the exact-zero rung
        x = jnp.asarray(np.r_[np.full(4, 5.0), np.zeros(1020)]
                        .astype(np.float32))
        s = sel.trimmed_topk(x, 8)
        assert int(s.count) == 8


class TestThresholdFilterOverflow:
    """Pinned overflow semantics when nnz(|x| > t) > capacity: the first
    ``capacity`` survivors in INDEX order are kept (lowest indices win,
    not largest magnitudes), count saturates, and ``overflow`` is set."""

    def test_overflow_keeps_first_capacity_lowest_indices(self):
        x = jnp.asarray(np.linspace(1.0, 2.0, 100).astype(np.float32))
        s = sel.threshold_filter(x, jnp.float32(0.5), capacity=16)
        assert bool(s.overflow)
        assert int(s.count) == 16
        assert list(map(int, s.indices)) == list(range(16))

    def test_nnz_above_2k_after_search(self):
        # eps-exhausted bisection can exit with nnz > 2k: a spike train
        # of identical magnitudes is indivisible by any threshold
        k = 4
        x = jnp.asarray(np.r_[np.full(64, 3.0), np.zeros(960)]
                        .astype(np.float32))
        s, thr = sel.threshold_binary_search(x, k)
        assert bool(s.overflow)
        assert int(s.count) == 2 * k
        assert list(map(int, s.indices)) == list(range(2 * k))

    def test_no_overflow_flag_clear(self):
        s = sel.threshold_filter(_vec(100), jnp.float32(100.0), capacity=8)
        assert not bool(s.overflow)
        assert int(s.count) == 0


class TestWarmStartedBisection:
    def test_warm_accepts_converged_threshold(self):
        x = _vec(20000, seed=8)
        k = 128
        s, thr = sel.threshold_binary_search(x, k)
        s2, thr2 = sel.threshold_binary_search(x, k, warm=thr)
        # the converged threshold is in band -> accepted verbatim
        assert float(thr2) == float(thr)
        np.testing.assert_array_equal(np.asarray(s2.indices),
                                      np.asarray(s.indices))

    def test_warm_zero_bitwise_cold(self):
        # warm=0 probes nnz(|x| > 0) >> 2k and seeds bracket (0, 1) --
        # bitwise the cold loop's iterate sequence
        x = _vec(8192, seed=9)
        k = 16
        s_cold, thr_cold = sel.threshold_binary_search(x, k)
        s_warm, thr_warm = sel.threshold_binary_search(
            x, k, warm=jnp.float32(0.0))
        assert float(thr_warm) == float(thr_cold)
        np.testing.assert_array_equal(np.asarray(s_warm.indices),
                                      np.asarray(s_cold.indices))

    def test_warm_out_of_band_still_lands_in_band(self):
        x = _vec(30000, seed=10)
        k = 64
        # a stale warm threshold way too high (nnz < k -> bracket below)
        s, _ = sel.threshold_binary_search(x, k, warm=jnp.float32(3.5))
        assert k <= int(s.count) <= 2 * k
        top = set(map(int, sel.exact_topk(x, k).indices))
        got = set(map(int, np.asarray(s.indices)[: int(s.count)]))
        assert top <= got


class TestSampledSearch:
    def test_tolerance_zero_bitwise_exact(self):
        x = _vec(50000, seed=11)
        k = 100
        s, thr = sel.threshold_binary_search(x, k)
        ss, thr_s = sel.sampled_threshold_search(x, k, stride=1,
                                                 capacity=2 * k)
        assert float(thr_s) == float(thr)
        np.testing.assert_array_equal(np.asarray(ss.indices),
                                      np.asarray(s.indices))
        np.testing.assert_array_equal(np.asarray(ss.values),
                                      np.asarray(s.values))

    @pytest.mark.parametrize("stride", [2, 4, 16])
    def test_sampled_selects_exact_filter_set(self, stride):
        """Whatever threshold the subsample search lands on, the emitted
        set is the EXACT filter at that threshold (selection error comes
        only from the threshold estimate, never the filter)."""
        x = _vec(40000, seed=12)
        k = 100
        cap = 2 * k + k  # tolerance headroom
        s, thr = sel.sampled_threshold_search(x, k, stride=stride,
                                              capacity=cap)
        ref = sel.threshold_filter(x, thr, capacity=cap)
        np.testing.assert_array_equal(np.asarray(s.indices),
                                      np.asarray(ref.indices))
        assert int(s.count) == int(ref.count)

    def test_sampled_stats_use_subsample(self):
        """The mean/max feeding the search come from x[::stride] — the
        documented estimator, pinned so the segmented twin can match it
        bitwise."""
        x = _vec(4096, seed=13)
        stride = 4
        sub = np.asarray(x)[::stride]
        axs = jnp.abs(jnp.asarray(sub))
        # degenerate warm: accept iff in band at the subsample count
        _, thr = sel.sampled_threshold_search(x, 8, stride=stride,
                                              capacity=32)
        assert 0.0 <= float(thr) <= float(jnp.max(axs))


def test_jit_compatible():
    x = _vec(2048)
    f = jax.jit(lambda v: sel.trimmed_topk(v, 8))
    s = f(x)
    assert int(s.count) == 8
    g = jax.jit(lambda v: sel.threshold_binary_search(v, 8))
    s2, thr = g(x)
    assert s2.indices.shape == (16,)
