"""Overlap-scheduler tests (§5.6): chunk-partitioner invariants
(hypothesis + deterministic grid twin), the chunked-vs-sequential
differential battery (every transport x fuse_leaves, in-process p=1 and
the 8-device subprocess cluster), per-chunk dispatch/lane accounting,
and the stale1 double-buffer semantics against a hand-rolled two-step
reference."""
import os

import numpy as np
import pytest

OVERLAP_PROG = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "_overlap_prog.py")

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SIZES = {"big": 300_000, "mid": 96 * 1024 + 3, "mid2": 33_001,
         "small": 1_000}
CHUNK_BYTES = 260_000


def _tree(seed=0, sizes=SIZES):
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    params = {k: jnp.asarray(rng.standard_normal(n), jnp.float32)
              for k, n in sizes.items()}
    grads = jax.tree.map(lambda p: p * 0.01, params)
    return params, grads


def _run(params, grads, schedule, steps=3, jit=True, timer=None, **kw):
    import jax
    import jax.numpy as jnp

    from repro.core import build_gradient_sync
    sync = build_gradient_sync(
        kw.pop("spec", "rgc"), sync_axes=(), density=0.01,
        dense_threshold_bytes=2048, schedule=schedule,
        bucket_bytes=kw.pop("bucket_bytes", CHUNK_BYTES), timer=timer,
        **kw)
    state = sync.init(params)
    step = (lambda p, st: sync.update(grads, st, p, jnp.float32(0.1)))
    if jit:
        step = jax.jit(step)
    p = params
    for _ in range(steps):
        p, state = step(p, state)
    return p, state


def _assert_bitwise(a, b):
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype
        assert np.array_equal(x, y, equal_nan=True), \
            f"max|d|={np.max(np.abs(x.astype(np.float64) - y))}"


# ---------------------------------------------------------------------------
# chunk partitioner invariants
# ---------------------------------------------------------------------------

def _check_partition(sizes, budget):
    from repro.core.overlap import partition_chunks
    chunks = partition_chunks(sizes, budget)
    # every leaf exactly once, never split, in exact REVERSE parameter
    # order across the chunk sequence
    flat = [i for c in chunks for i in c.leaves]
    assert flat == list(reversed(range(len(sizes))))
    assert [c.cid for c in chunks] == list(range(len(chunks)))
    for c in chunks:
        assert c.nbytes == sum(sizes[i] for i in c.leaves)
        # byte budget respected, except a single oversized leaf
        assert c.nbytes <= budget or len(c.leaves) == 1
    # greedy maximality: the next chunk's first leaf would not have fit
    for a, b in zip(chunks, chunks[1:]):
        assert a.nbytes + sizes[b.leaves[0]] > budget


def test_partition_grid():
    """Deterministic twin of the hypothesis property (runs even without
    hypothesis installed)."""
    grids = [
        ([4], 4), ([4], 1), ([1, 2, 3, 4, 5], 5), ([5, 4, 3, 2, 1], 5),
        ([10, 10, 10], 10), ([10, 10, 10], 30), ([10, 10, 10], 29),
        ([100, 1, 1, 1, 100], 3), ([7] * 13, 20), ([1] * 64, 8),
        ([1 << 22, 128, 1 << 22], 1 << 20),
    ]
    for sizes, budget in grids:
        _check_partition(sizes, budget)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_partition_property():
    @given(st.lists(st.integers(1, 5000), min_size=1, max_size=40),
           st.integers(1, 20_000))
    @settings(max_examples=100, deadline=None)
    def prop(sizes, budget):
        _check_partition(sizes, budget)
    prop()


def test_partition_rejects_bad_budget():
    from repro.core.overlap import partition_chunks
    with pytest.raises(ValueError):
        partition_chunks([1, 2], 0)
    with pytest.raises(ValueError):
        partition_chunks([1, 2], -4)


def test_chunk_plans_cover_all_arena_slots():
    """The per-chunk plans partition the leaf set exactly: every leaf
    lands in exactly one chunk plan, as an arena slot, a per-leaf sparse
    unit, or a dense unit — never twice, never split."""
    import jax

    from repro.core import build_gradient_sync
    params, grads = _tree()
    sync = build_gradient_sync("rgc", sync_axes=(), density=0.01,
                               dense_threshold_bytes=2048,
                               schedule="chunked",
                               bucket_bytes=CHUNK_BYTES)
    leaves_g, treedef = jax.tree.flatten(grads)
    plans = sync._chunk_plans(grads, treedef, leaves_g, 0.01, False)
    assert len(plans) >= 2, "tree did not split into multiple chunks"
    seen = []
    for plan in plans:
        for g in plan.groups:
            seen.extend(slot.leaf for slot in g.slots)
        seen.extend(i for i, _, _ in plan.sparse)
        seen.extend(plan.dense)
    assert sorted(seen) == list(range(len(leaves_g)))
    assert len(seen) == len(set(seen))


# ---------------------------------------------------------------------------
# chunked == sequential differential (single worker, jit, all transports)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("transport", ["fused_allgather",
                                       "bucketed_allgather",
                                       "per_leaf_allgather",
                                       "hierarchical"])
def test_chunked_bitwise_sequential(transport, fuse):
    params, grads = _tree()
    ref = _run(params, grads, "sequential", transport=transport,
               fuse_leaves=fuse)
    got = _run(params, grads, "chunked", transport=transport,
               fuse_leaves=fuse)
    _assert_bitwise(got, ref)


def test_chunked_bitwise_sequential_with_corrections():
    params, grads = _tree()
    kw = dict(spec="momentum+clip(threshold_bsearch)", local_clip=1.0,
              momentum=0.9)
    ref = _run(params, grads, "sequential", **kw)
    got = _run(params, grads, "chunked", **kw)
    _assert_bitwise(got, ref)


def test_chunked_all_dense_matches_sequential():
    """density >= 1.0 sentinel (§5.7 warm-up): chunked still bitwise."""
    import jax
    import jax.numpy as jnp

    from repro.core import build_gradient_sync
    params, grads = _tree()

    def run(schedule):
        sync = build_gradient_sync("rgc", sync_axes=(), density=0.01,
                                   schedule=schedule,
                                   bucket_bytes=CHUNK_BYTES)
        state = sync.init(params)
        return jax.jit(lambda p, st: sync.update(
            grads, st, p, jnp.float32(0.1), density=1.0))(params, state)

    _assert_bitwise(run("chunked"), run("sequential"))


# ---------------------------------------------------------------------------
# dispatch accounting: the pipelining is real, not a silent fallback
# ---------------------------------------------------------------------------

def test_chunked_issues_multiple_transport_dispatches():
    """chunked must dispatch >= 2 transport collectives per step (one per
    chunk carrying sparse messages) where sequential dispatches exactly
    one fused collective."""
    from repro.core import WallClockTimer
    params, grads = _tree()
    steps = 2

    t_seq = WallClockTimer()
    _run(params, grads, "sequential", steps=steps, jit=False, timer=t_seq)
    t_chk = WallClockTimer()
    _run(params, grads, "chunked", steps=steps, jit=False, timer=t_chk)

    seq = t_seq.summary()["counts"]["collectives"] / steps
    chk = t_chk.summary()["counts"]["collectives"] / steps
    assert seq == 1
    assert chk >= 2, f"chunked fell back to one barrier ({chk}/step)"
    # same messages in total, just spread over more dispatches
    assert (t_chk.summary()["counts"]["messages"]
            >= t_seq.summary()["counts"]["messages"])


def test_chunk_lanes_recorded():
    """The per-chunk StageTimer lane: every chunk gets its own stage
    attribution, under the Fig 10 stage names."""
    from repro.core import WallClockTimer
    timer = WallClockTimer()
    params, grads = _tree()
    _run(params, grads, "chunked", steps=1, jit=False, timer=timer)
    lanes = timer.summary().get("lanes", {})
    assert len(lanes) >= 2
    for lane, stages in lanes.items():
        assert lane.startswith("chunk")
        assert "select" in stages or "transfer" in stages
    # lane stage names are a subset of the canonical stage set
    from repro.core import STAGES
    for stages in lanes.values():
        assert set(stages) <= set(STAGES)


# ---------------------------------------------------------------------------
# stale1: hand-rolled two-step reference + guards
# ---------------------------------------------------------------------------

def test_stale1_matches_hand_rolled_reference():
    """One tiny leaf, exact_topk, no momentum, single worker: stale1 must
    equal a hand-rolled Alg 4 loop that applies each step's selection one
    step late (zero-count at t=0) — bitwise, params and residual."""
    import jax
    import jax.numpy as jnp

    from repro.core import build_gradient_sync, selection
    rng = np.random.default_rng(7)
    n, k, steps, lr = 64, 4, 5, 0.1
    params = {"w": jnp.asarray(rng.standard_normal(n), jnp.float32)}
    grads = [{"w": jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)}
             for _ in range(steps)]

    sync = build_gradient_sync("exact_topk", sync_axes=(),
                               density=k / n, momentum=0.0,
                               schedule="stale1")
    state = sync.init(params)
    p = params
    for t in range(steps):
        p, state = sync.update(grads[t], state, p, jnp.float32(lr))

    # hand-rolled: residual accumulate -> exact top-k select -> mask,
    # apply the PREVIOUS selection (nothing at t=0)
    w = params["w"]
    resid = jnp.zeros(n, jnp.float32)
    prev = None
    for t in range(steps):
        v = resid + grads[t]["w"].astype(jnp.float32)
        sel = selection.exact_topk(v, k)
        resid = v.at[sel.indices].set(0.0, mode="drop")
        if prev is not None:
            dense = jnp.zeros(n, jnp.float32).at[prev.indices].add(
                prev.values, mode="drop")
            w = (w.astype(jnp.float32) - lr * (dense / 1)).astype(w.dtype)
        prev = sel

    np.testing.assert_array_equal(np.asarray(p["w"]), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(state.leaf["w"].residual),
                                  np.asarray(resid))
    # the pending buffer holds exactly the LAST step's packed message
    from repro.core import sync as sync_lib
    np.testing.assert_array_equal(np.asarray(state.pending[0]),
                                  np.asarray(sync_lib.pack(prev, False)))


def test_stale1_first_step_applies_nothing():
    """Step 0 communicates the zero-count init buffer: params must not
    move on the sparse path (dense leaves DO move — they stay sync)."""
    import jax.numpy as jnp

    from repro.core import build_gradient_sync
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal(50_000), jnp.float32)}
    grads = {"w": jnp.asarray(rng.standard_normal(50_000), jnp.float32)}
    sync = build_gradient_sync("threshold_bsearch", sync_axes=(),
                               density=0.01, momentum=0.0,
                               schedule="stale1")
    state = sync.init(params)
    p1, state = sync.update(grads, state, params, jnp.float32(0.1))
    np.testing.assert_array_equal(np.asarray(p1["w"]),
                                  np.asarray(params["w"]))
    # second step applies step 0's selection
    p2, state = sync.update(grads, state, p1, jnp.float32(0.1))
    assert np.max(np.abs(np.asarray(p2["w"])
                         - np.asarray(p1["w"]))) > 0


def test_stale1_rejects_density_ramp():
    import jax.numpy as jnp
    import pytest

    from repro.core import build_gradient_sync
    params, grads = _tree(sizes={"w": 4_000})
    sync = build_gradient_sync("rgc", sync_axes=(), density=0.01,
                               schedule="stale1")
    state = sync.init(params)
    with pytest.raises(ValueError, match="fixed target density"):
        sync.update(grads, state, params, jnp.float32(0.1), density=0.25)
    # the dense warm-up sentinel is fine
    sync.update(grads, state, params, jnp.float32(0.1), density=1.0)


def test_stale1_dense_step_carries_pending_through():
    """A §5.7 dense step (density >= 1.0) must carry the pending buffer
    through UNTOUCHED: zero-count during an initial warm-up (the first
    sparse step applies nothing stale), and — if a dense step is
    interleaved after sparse training — still holding the prior sparse
    step's packed values, which may only be applied later, never
    dropped."""
    import jax.numpy as jnp

    from repro.core import build_gradient_sync
    params, grads = _tree(sizes={"w": 50_000})
    sync = build_gradient_sync("rgc", sync_axes=(), density=0.01,
                               schedule="stale1")
    state = sync.init(params)
    p, state = sync.update(grads, state, params, jnp.float32(0.1),
                           density=1.0)
    for m in state.pending:
        assert not np.asarray(m).any()
    # sparse step packs a real message; an interleaved dense step must
    # preserve it bitwise
    p, state = sync.update(grads, state, p, jnp.float32(0.1))
    packed = [np.asarray(m) for m in state.pending]
    assert any(m.any() for m in packed)
    p, state = sync.update(grads, state, p, jnp.float32(0.1),
                           density=1.0)
    for got, want in zip(state.pending, packed):
        np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# registry / config plumbing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", ["sequential", "chunked", "stale1"])
def test_plan_sees_raw_gradient_dtype_through_update(schedule):
    """§5.5 dispatch must see the RAW gradient storage dtype even when a
    correction upcasts the compute leaves (local_clip's pinned_product
    promotes bf16 -> f32): a 96 KB bf16 leaf stays DENSE through a full
    ``update`` under every schedule — the PR 1/PR 4 raw-itemsize rule,
    pinned through the schedule path, not just ``_plan`` directly."""
    import jax
    import jax.numpy as jnp

    from repro.core import build_gradient_sync
    rng = np.random.default_rng(0)
    n = 48 * 1024                     # bf16: 96 KB < 128 KB -> dense;
    #                                   an f32 view would be 192 KB -> sparse
    params = {"w": jnp.asarray(rng.standard_normal(n), jnp.bfloat16)}
    grads = {"w": jnp.asarray(rng.standard_normal(n) * 0.01, jnp.bfloat16)}
    sync = build_gradient_sync("rgc", sync_axes=(), density=0.01,
                               local_clip=1.0, schedule=schedule)
    state = sync.init(params)
    sync.update(grads, state, params, jnp.float32(0.1))

    # the cache holds _StepPlan entries (themselves NamedTuples) and, for
    # chunked, tuples OF plans — flatten by duck type
    plans = [p for v in sync._plans.values()
             for p in ((v,) if hasattr(v, "dense") else v)]
    assert plans
    for plan in plans:
        assert plan.dense == (0,), \
            f"bf16 96KB leaf mis-dispatched sparse: {plan}"
        assert not plan.sparse and not plan.groups


def test_schedule_registry_names():
    from repro.core import registry
    assert set(registry.names(registry.SCHEDULE)) == {
        "sequential", "chunked", "stale1"}


def test_build_rejects_unknown_schedule():
    from repro.core import build_gradient_sync
    with pytest.raises(KeyError):
        build_gradient_sync("rgc", schedule="warp_speed")


def test_trainer_chunked_bitwise_sequential():
    """Real Trainer, single device: a chunked run's params must be
    bitwise identical to the sequential run's after several steps."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import TrainConfig, get_config
    from repro.data import bigram_batches
    from repro.train.trainer import Trainer

    cfg = get_config("paper-lstm", smoke=True)

    def run(schedule):
        tc = TrainConfig(lr=0.5, density=0.05, optimizer="rgc",
                         local_clip=1.0, schedule=schedule,
                         bucket_bytes=200_000)
        tr = Trainer(cfg, tc)
        state = tr.init_state()
        return tr.run(state, bigram_batches(cfg.vocab_size, 4, 32, seed=2),
                      3, log_every=0)

    ref, got = run("sequential"), run("chunked")
    _assert_bitwise(got.params, ref.params)
    _assert_bitwise(got.rgc, ref.rgc)


def test_trainer_stale1_runs_and_learns_smoke():
    """stale1 through the real Trainer (single device): state plumbs
    through init/run and the loss trajectory still trends down."""
    from repro.configs import TrainConfig, get_config
    from repro.data import bigram_batches
    from repro.train.trainer import Trainer

    cfg = get_config("paper-lstm", smoke=True)
    tc = TrainConfig(lr=0.5, density=0.05, optimizer="rgc",
                     local_clip=1.0, schedule="stale1")
    tr = Trainer(cfg, tc)
    state = tr.init_state()
    losses = []
    tr.run(state, bigram_batches(cfg.vocab_size, 8, 64, seed=2), 30,
           log_every=0,
           on_metrics=lambda step, dens, loss: losses.append(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


# ---------------------------------------------------------------------------
# the 8-device differential battery (subprocess: forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", ["fused", "bucketed", "per_leaf",
                                  "hierarchical", "corrections", "stale1"])
def test_overlap_prog_8dev(run_prog, case):
    """chunked bitwise == sequential (params + state + sha256 digest) per
    transport x fuse_leaves on the 8-device simulated cluster; stale1
    vs the explicitly delayed sequential reference."""
    run_prog(OVERLAP_PROG, case)
