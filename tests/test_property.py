"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (see "
                    "requirements-dev.txt); skipping property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import registry
from repro.core import selection as sel
from repro.core import sync
from repro.core.cost_model import (MURADIN, PIZ_DAINT, TPU_V5E, bandwidth_ratio,
                                   choose_method, t_dense, t_sparse)
from repro.core.residual import mask_communicated

_settings = settings(max_examples=30, deadline=None)


def vec_and_k():
    return st.integers(10, 2000).flatmap(
        lambda n: st.tuples(
            st.just(n),
            st.integers(1, max(1, n // 4)),
            st.integers(0, 2**31 - 1)))


@given(vec_and_k())
@_settings
def test_trimmed_topk_is_exact_topk(args):
    """Alg 2 invariant: the trimmed selection equals the exact top-k set."""
    n, k, seed = args
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = sel.trimmed_topk(x, k)
    want = sel.exact_topk(x, k)
    assert set(map(int, got.indices)) == set(map(int, want.indices))


@given(vec_and_k())
@_settings
def test_bsearch_invariants(args):
    """Alg 3 invariants: (a) indices valid; (b) count <= 2k; (c) the top-k
    set is always contained; (d) padded slots carry sentinel index."""
    n, k, seed = args
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    s, thr = sel.threshold_binary_search(x, k)
    cnt = int(s.count)
    idx = np.asarray(s.indices)
    assert 1 <= cnt <= 2 * k
    assert np.all((idx[:cnt] >= 0) & (idx[:cnt] < n))
    assert np.all(idx[cnt:] == n)
    top = set(map(int, sel.exact_topk(x, min(k, cnt)).indices))
    assert top <= set(map(int, idx[:cnt]))


@given(vec_and_k(), st.booleans())
@_settings
def test_pack_unpack_roundtrip(args, quantized):
    """decompress(pack(sel)) scatters exactly the selected (or quantized)
    values — the single-worker sparse-sync identity."""
    n, k, seed = args
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    if quantized:
        s = sel.exact_topk_quant(x, k, jnp.int32(seed % 2))
    else:
        s = sel.exact_topk(x, k)
    msg = sync.pack(s, quantized)
    dense = sync.unpack_decompress(msg[None], n, s.indices.shape[0],
                                   quantized)
    expect = np.zeros(n, np.float32)
    cnt = int(s.count)
    idx = np.asarray(s.indices)[:cnt]
    vals = np.asarray(s.values)[:cnt]
    np.add.at(expect, idx, vals)
    np.testing.assert_allclose(np.asarray(dense), expect, rtol=1e-6,
                               atol=1e-7)


@given(st.lists(st.integers(5, 200), min_size=1, max_size=6),
       st.integers(0, 2**31 - 1))
@_settings
def test_fused_allgather_split_roundtrip(lens, seed):
    """Tensor fusion: concat -> (1-worker) allgather -> split restores every
    per-leaf segment bit-exactly."""
    rng = np.random.default_rng(seed)
    msgs = [jnp.asarray(rng.standard_normal(l), jnp.float32) for l in lens]
    out = sync.fused_allgather(msgs, axes=())
    for m, o in zip(msgs, out):
        assert o.shape == (1, m.shape[0])
        np.testing.assert_array_equal(np.asarray(o[0]), np.asarray(m))


@given(st.integers(2, 1024), st.floats(1e-4, 0.05),
       st.sampled_from([MURADIN, PIZ_DAINT, TPU_V5E]))
@_settings
def test_cost_model_positive_and_monotone(p, density, net):
    m = 64 * 1024 * 1024 // 4
    ts = t_sparse(p, m, density, net)
    td = t_dense(p, m, net)
    assert ts > 0 and td > 0
    # sparse bandwidth term grows with p (the paper's §5.5 observation)
    if p >= 4:
        assert t_sparse(2 * p, m, density, net) > ts


@given(st.integers(2, 4096))
@_settings
def test_bandwidth_ratio_formula(p):
    """§5.5: sparse/dense bandwidth ratio = p*D/2 — model compression is NOT
    wire compression (p=128, D=0.1% -> 6.4%)."""
    d = 0.001
    np.testing.assert_allclose(bandwidth_ratio(p, d), p * d / 2, rtol=1e-9)


@given(st.integers(1, 10**9))
@_settings
def test_choose_method_total(nbytes):
    m = choose_method(nbytes)
    assert m in ("dense", "trimmed_topk", "threshold_binary_search")
    if nbytes < 128 * 1024:
        assert m == "dense"
    elif nbytes < 4 * 1024 * 1024:
        assert m == "trimmed_topk"
    else:
        assert m == "threshold_binary_search"


@given(st.integers(10, 500), st.integers(1, 20), st.integers(0, 2**31 - 1))
@_settings
def test_quantized_message_halves_payload(n, k, seed):
    """§5.2.3: quantized wire message = count + indices + ONE scalar."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    k = min(k, n)
    s = sel.exact_topk_quant(x, k, jnp.int32(0))
    assert sync.pack(s, True).shape[0] == 1 + k + 1
    assert sync.pack(s, False).shape[0] == 1 + 2 * k


# ---------------------------------------------------------------------------
# the Compressor API contract, for EVERY registered compressor
# ---------------------------------------------------------------------------

_SELECTING = sorted(n for n in registry.names(registry.COMPRESSOR)
                    if n != "dense")


def _roundtrip(comp, x, k):
    tr = registry.make(registry.TRANSPORT, "fused_allgather", sync_axes=())
    state = comp.init_leaf(x, momentum=False)._replace(residual=x)
    s, state = comp.compress(x, k, state)
    state = mask_communicated(state, s.indices, momentum=False)
    (gathered,) = tr.allgather([tr.pack(s, comp.quantized)])
    return s, state.residual, comp.decompress(gathered, x.size, k)


@pytest.mark.parametrize("name", _SELECTING)
@given(vec_and_k())
@_settings
def test_compressor_mass_conservation(name, args):
    """decompress(msg) + residual == grad — exact (bitwise) for plain
    selectors; total-communicated-mass conservation for quantized ones."""
    n, k, seed = args
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    comp = registry.make(registry.COMPRESSOR, name)
    s, residual, dense = _roundtrip(comp, x, k)
    if comp.quantized:
        np.testing.assert_allclose(float(jnp.sum(dense)),
                                   float(jnp.sum(s.values)),
                                   rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_array_equal(np.asarray(residual + dense),
                                      np.asarray(x))


@pytest.mark.parametrize("name", _SELECTING)
@given(vec_and_k())
@_settings
def test_compressor_count_capacity_dtype(name, args):
    """count <= capacity, indices valid + sentinel-padded, f32 wire values,
    bf16 residual dtype preserved through compress+mask."""
    n, k, seed = args
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    comp = registry.make(registry.COMPRESSOR, name)
    s, residual, _ = _roundtrip(comp, x, k)
    cap = comp.capacity(k)
    cnt = int(s.count)
    idx = np.asarray(s.indices)
    assert 1 <= cnt <= cap
    assert np.all((idx[:cnt] >= 0) & (idx[:cnt] < n))
    assert np.all(idx[cnt:] == n)
    assert s.values.dtype == jnp.float32
    assert residual.dtype == x.dtype

    bst = comp.init_leaf(x, momentum=False, residual_dtype=jnp.bfloat16)
    s2, bst2 = comp.compress(x, k, bst)
    assert mask_communicated(bst2, s2.indices,
                             momentum=False).residual.dtype == jnp.bfloat16


@pytest.mark.parametrize("name", _SELECTING)
@given(st.integers(100, 1500), st.integers(1, 24), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_compressor_deterministic_under_jit(name, n, k, seed):
    k = min(k, n // 4 + 1)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    comp = registry.make(registry.COMPRESSOR, name)
    st0 = comp.init_leaf(x, momentum=False)

    def f(v, state):
        s, state = comp.compress(v, k, state)
        return s.indices, s.values, s.count

    jitted = jax.jit(f)
    first, second, eager = jitted(x, st0), jitted(x, st0), f(x, st0)
    for a, b, c in zip(first, second, eager):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


@given(st.integers(64, 4000), st.integers(1, 40), st.integers(0, 2**31 - 1),
       st.sampled_from([128, 256, 1024]))
@settings(max_examples=15, deadline=None)
def test_pallas_trimmed_topk_matches_exact(n, k, seed, block):
    """Kernel-path trimmed top-k == exact top-k set for arbitrary shapes,
    block sizes and ks (stresses the bucket-overflow fallback)."""
    from repro.kernels import ops
    k = min(k, n // 2 + 1)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = ops.trimmed_topk(x, k, block=block)
    want = sel.exact_topk(x, k)
    assert set(map(int, got.indices)) == set(map(int, want.indices))
