"""Composable compression API: registry round-trip, per-compressor
compress→decompress identity, dtype-aware dispatch, and bitwise parity of
the composed ``GradientSync`` pipeline against the frozen legacy
``rgc_apply`` monolith (tests/_legacy_rgc.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import registry
from repro.core.dispatch import FixedPolicy, SizeBasedPolicy, leaf_nbytes
from repro.core.gradient_sync import build_gradient_sync
from repro.core.rgc import RGCConfig, gradient_sync_from_rgc_config
from repro.core.sync import message_len
from repro.models.registry import get_model

from _legacy_rgc import legacy_rgc_apply, legacy_rgc_init

SELECTING = ["exact_topk", "trimmed_topk", "threshold_bsearch"]
QUANTIZED = [f"quantized({n})" for n in SELECTING]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_every_compressor_constructible_by_name(self):
        names = registry.names(registry.COMPRESSOR)
        assert {"dense", "exact_topk", "trimmed_topk",
                "threshold_bsearch", "quantized"} <= set(names)
        for name in names:
            comp = registry.make(registry.COMPRESSOR, name)
            assert hasattr(comp, "compress")
            assert comp.capacity(8) >= (0 if name == "dense" else 8)

    def test_every_transport_constructible_by_name(self):
        names = registry.names(registry.TRANSPORT)
        assert set(names) == {"fused_allgather", "bucketed_allgather",
                              "hierarchical", "per_leaf_allgather",
                              "dense_psum"}
        for name in names:
            tr = registry.make(registry.TRANSPORT, name, sync_axes=())
            assert tr.num_workers() == 1

    def test_every_policy_constructible_by_name(self):
        for name in registry.names(registry.DISPATCH_POLICY):
            pol = registry.make(registry.DISPATCH_POLICY, name)
            assert pol.compressor_for("", jnp.zeros((4,))) in \
                registry.names(registry.COMPRESSOR)

    def test_nested_spec(self):
        comp = registry.make(registry.COMPRESSOR, "quantized(trimmed_topk)")
        assert comp.quantized and comp.inner.name == "trimmed_topk"
        assert comp.capacity(8) == 8

    def test_unknown_names_raise(self):
        with pytest.raises(KeyError):
            registry.make(registry.COMPRESSOR, "nope")
        with pytest.raises(KeyError):
            registry.make(registry.COMPRESSOR, "quantized(nope)")
        with pytest.raises(ValueError):
            build_gradient_sync("nope")

    def test_params_threaded_to_factories(self):
        comp = registry.make(registry.COMPRESSOR, "threshold_bsearch",
                             bsearch_interval=7, backend="jnp",
                             unrelated_param=1)
        assert comp.interval == 7


# ---------------------------------------------------------------------------
# compress -> pack -> (1-worker) allgather -> decompress identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SELECTING + QUANTIZED)
def test_compress_decompress_identity(name):
    n, k = 512, 16
    rng = np.random.default_rng(sum(map(ord, name)))
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    comp = registry.make(registry.COMPRESSOR, name)
    tr = registry.make(registry.TRANSPORT, "fused_allgather", sync_axes=())

    st = comp.init_leaf(x, momentum=False)
    sel, _ = comp.compress(x, k, st)
    msg = tr.pack(sel, comp.quantized)
    assert msg.shape[0] == message_len(comp.capacity(k), comp.quantized)

    (gathered,) = tr.allgather([msg])
    dense = np.asarray(comp.decompress(gathered, n, k))

    cnt = int(sel.count)
    assert 1 <= cnt <= comp.capacity(k)
    idx = np.asarray(sel.indices)
    assert np.all(idx[cnt:] == n)          # padding slots carry sentinel
    expect = np.zeros(n, np.float32)
    np.add.at(expect, idx[:cnt], np.asarray(sel.values)[:cnt])
    np.testing.assert_allclose(dense, expect, rtol=1e-6, atol=1e-6)
    if comp.quantized:                     # single shared magnitude
        nz = dense[dense != 0]
        assert nz.size == cnt and np.allclose(nz, nz[0])


# ---------------------------------------------------------------------------
# dtype-aware dispatch (the leaf_bytes bug fix)
# ---------------------------------------------------------------------------

class TestDispatch:
    def test_leaf_nbytes_uses_real_itemsize(self):
        assert leaf_nbytes(jnp.zeros((100,), jnp.float32)) == 400
        assert leaf_nbytes(jnp.zeros((100,), jnp.bfloat16)) == 200
        assert leaf_nbytes(jnp.zeros((100,), jnp.int8)) == 100
        # works on abstract leaves too (dryrun eval_shape path)
        assert leaf_nbytes(jax.ShapeDtypeStruct((64, 64), jnp.bfloat16)) \
            == 8192

    def test_bf16_dispatch_regression(self):
        """A 48K-element bf16 leaf is 96 KB — below the 128 KB dense
        boundary. The seed's 4-bytes/element assumption called it 192 KB
        and mis-dispatched it to trimmed_topk."""
        policy = SizeBasedPolicy()
        bf16 = jax.ShapeDtypeStruct((48 * 1024,), jnp.bfloat16)
        f32 = jax.ShapeDtypeStruct((48 * 1024,), jnp.float32)
        assert policy.compressor_for("", bf16) == "dense"
        assert policy.compressor_for("", f32) == "trimmed_topk"
        # same story at the 4 MB trimmed/bsearch boundary
        bf16_big = jax.ShapeDtypeStruct((1536 * 1024,), jnp.bfloat16)  # 3 MB
        f32_big = jax.ShapeDtypeStruct((1536 * 1024,), jnp.float32)   # 6 MB
        assert policy.compressor_for("", bf16_big) == "trimmed_topk"
        assert policy.compressor_for("", f32_big) == "threshold_bsearch"

    def test_fixed_policy(self):
        pol = FixedPolicy("exact_topk")
        assert pol.compressor_for("any", jnp.zeros((2,))) == "exact_topk"


# ---------------------------------------------------------------------------
# bitwise parity: GradientSync == the frozen legacy monolith
# ---------------------------------------------------------------------------

# thresholds sized so smoke-model leaves land on all three §5.5 methods
_TH = dict(dense_threshold_bytes=1024, trimmed_threshold_bytes=64 * 1024)

PARITY_CFGS = {
    "rgc_mix": RGCConfig(density=0.02, momentum=0.9, sync_axes=(),
                         bsearch_interval=2, **_TH),
    "rgc_quant": RGCConfig(density=0.02, momentum=0.0, quantize=True,
                           sync_axes=(), **_TH),
    "dense_warmup": RGCConfig(density=1.0, momentum=0.9, sync_axes=(),
                              **_TH),
    "clip_wd_nesterov_unfused": RGCConfig(
        density=0.02, momentum=0.9, nesterov=True, weight_decay=1e-4,
        local_clip=1.0, fuse_messages=False, sync_axes=(), **_TH),
}


def _f32_model(arch):
    cfg = get_config(arch, smoke=True)
    # parity must hold where the seed's 4-byte assumption was correct;
    # bf16 dispatch intentionally differs (see TestDispatch)
    return get_model(dataclasses.replace(cfg, dtype=jnp.float32))


def _grads_like(params, step):
    leaves, treedef = jax.tree.flatten(params)
    out = []
    for j, p in enumerate(leaves):
        rng = np.random.default_rng(1000 * step + j)
        out.append(jnp.asarray(rng.standard_normal(p.shape) * 0.1,
                               jnp.float32).astype(p.dtype))
    return jax.tree.unflatten(treedef, out)


def _assert_trees_bitwise(a, b, what):
    la, ta = jax.tree_util.tree_flatten_with_path(a)
    lb, _ = jax.tree_util.tree_flatten_with_path(b)
    assert len(la) == len(lb)
    for (kp, xa), (_, xb) in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(xa), np.asarray(xb),
            err_msg=f"{what} mismatch at {jax.tree_util.keystr(kp)}")


@pytest.mark.parametrize("arch", ["paper-lstm", "internlm2-1.8b"])
@pytest.mark.parametrize("cfg_name", sorted(PARITY_CFGS))
def test_gradient_sync_matches_legacy_bitwise(arch, cfg_name):
    cfg = PARITY_CFGS[cfg_name]
    model = _f32_model(arch)
    params = model.init_params(0)

    if cfg_name != "dense_warmup":
        # the run must actually exercise the sparse paths
        policy = SizeBasedPolicy(cfg.dense_threshold_bytes,
                                 cfg.trimmed_threshold_bytes)
        methods = {policy.compressor_for("", p)
                   for p in jax.tree.leaves(params)}
        assert {"trimmed_topk", "threshold_bsearch"} <= methods

    sync = gradient_sync_from_rgc_config(cfg)
    lp, ls = params, legacy_rgc_init(params, cfg)
    np_, ns = params, sync.init(params)
    _assert_trees_bitwise(ls, ns, "init state")

    lr = jnp.float32(0.1)
    for step in range(3):
        g = _grads_like(params, step)
        lp, ls = legacy_rgc_apply(g, lp, ls, lr=lr, cfg=cfg)
        np_, ns = sync.update(g, ns, np_, lr)
        _assert_trees_bitwise(lp, np_, f"params (step {step})")
        _assert_trees_bitwise(ls, ns, f"state (step {step})")


# ---------------------------------------------------------------------------
# registered compressor names train end-to-end through Trainer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("optimizer", ["threshold_bsearch",
                                       "quantized(exact_topk)"])
def test_registered_optimizer_trains_end_to_end(optimizer):
    from repro.data import bigram_batches
    from repro.train.trainer import Trainer

    cfg = get_config("internlm2-1.8b", smoke=True)
    tc = TrainConfig(lr=0.2, momentum=0.9, optimizer=optimizer,
                     density=0.01)
    tr = Trainer(cfg, tc)
    state = tr.init_state()
    losses = []
    state = tr.run(state, bigram_batches(cfg.vocab_size, 2, 32, seed=0),
                   3, log_every=1, log_fn=lambda s: losses.append(s))
    assert state.step == 3
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_per_leaf_transport_trains_end_to_end():
    from repro.data import bigram_batches
    from repro.train.trainer import Trainer

    cfg = get_config("internlm2-1.8b", smoke=True)
    tc = TrainConfig(lr=0.2, optimizer="rgc", density=0.01,
                     transport="per_leaf_allgather")
    tr = Trainer(cfg, tc)
    state = tr.run(tr.init_state(),
                   bigram_batches(cfg.vocab_size, 2, 32, seed=0),
                   2, log_every=0)
    assert state.step == 2
