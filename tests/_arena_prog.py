"""Multi-device flat-arena parity program, run as a subprocess by
tests/test_arena.py with 8 forced host devices (the XLA flag must be set
before jax init, so it cannot run inside the main pytest process).

Checks that ``fuse_leaves=True`` (flat residual arenas: one fused
accumulate-gather + segmented select + mask + pack per arena) produces
BITWISE identical synced params and residual state to the per-leaf
pipeline when every worker compresses a different local gradient:

 1. mixed-size tree (both §5.5 sparse classes + dense fallback leaves,
    non-block-multiple sizes) on the ("data",)=8 mesh, multi-step;
 2. the same with DGC corrections ("momentum+clip(threshold_bsearch)");
 3. a single-leaf model (one slot per arena — nothing to coalesce);
 4. fused arenas feeding the bucketed transport (arena messages ride
    straight into bucket assignment).
"""
import sys

from harness.cluster import check, force_host_devices

force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import build_gradient_sync
from repro.jaxcompat import shard_map as shard_map_compat
from repro.launch.mesh import _make_mesh

STEPS = 3
LR = 0.1

TREE_SIZES = {"big": (1 << 20) + 17, "mid": 96 * 1024 + 3,
              "mid2": 33_001, "small": 1_000}
SINGLE_SIZES = {"w": (1 << 20) + 17}


def run_steps(fuse, sizes, optimizer="rgc", **kw):
    mesh = _make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    params = {k: jnp.asarray(rng.standard_normal(n), jnp.float32)
              for k, n in sizes.items()}
    grads = {k: jnp.asarray(rng.standard_normal((8, STEPS, n)) * 0.01,
                            jnp.float32)
             for k, n in sizes.items()}

    sync = build_gradient_sync(
        optimizer, sync_axes=("data",), density=0.01, momentum=0.9,
        fuse_leaves=fuse, **kw)
    state0 = sync.init(params)

    def worker(gs, p, st):
        for t in range(STEPS):
            g_t = {k: g[0, t] for k, g in gs.items()}
            p, st = sync.update(g_t, st, p, jnp.float32(LR))
        return p, st

    f = jax.jit(shard_map_compat(
        worker, mesh=mesh,
        in_specs=({k: P(("data",)) for k in sizes}, P(),
                  jax.tree.map(lambda _: P(), state0)),
        out_specs=(P(), jax.tree.map(lambda _: P(), state0)),
        check_vma=False))
    p2, st2 = f(grads, params, state0)
    return (jax.tree.map(np.asarray, p2), jax.tree.map(np.asarray, st2))


def check_bitwise(name, got, want):
    leaves_g = jax.tree.leaves(got)
    leaves_w = jax.tree.leaves(want)
    same = all(a.dtype == b.dtype and np.array_equal(a, b, equal_nan=True)
               for a, b in zip(leaves_g, leaves_w))
    if not same:
        for a, b in zip(leaves_g, leaves_w):
            if not np.array_equal(a, b, equal_nan=True):
                print(f"  mismatch: max|d|="
                      f"{np.max(np.abs(a.astype(np.float64) - b)):.3e}")
    check(name, same)


def test_mixed_tree():
    ref_p, ref_s = run_steps(False, TREE_SIZES)
    got_p, got_s = run_steps(True, TREE_SIZES)
    check_bitwise("arena == per-leaf params (mixed tree, 8 workers)",
                  got_p, ref_p)
    check_bitwise("arena == per-leaf state (mixed tree, 8 workers)",
                  got_s, ref_s)


def test_corrections():
    spec = "momentum+clip(threshold_bsearch)"
    ref = run_steps(False, TREE_SIZES, optimizer=spec, local_clip=1.0)
    got = run_steps(True, TREE_SIZES, optimizer=spec, local_clip=1.0)
    check_bitwise("arena == per-leaf (DGC corrections, 8 workers)",
                  got, ref)


def test_single_leaf():
    ref = run_steps(False, SINGLE_SIZES)
    got = run_steps(True, SINGLE_SIZES)
    check_bitwise("arena == per-leaf (single-leaf model)", got, ref)


def test_bucketed_transport():
    kw = dict(transport="bucketed_allgather", bucket_bytes=40_000)
    ref = run_steps(False, TREE_SIZES, **kw)
    got = run_steps(True, TREE_SIZES, **kw)
    check_bitwise("arena == per-leaf (bucketed transport)", got, ref)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    fns = {"mixed": test_mixed_tree,
           "corrections": test_corrections,
           "single": test_single_leaf,
           "bucketed": test_bucketed_transport}
    if which == "all":
        for fn in fns.values():
            fn()
    else:
        fns[which]()
    print("OK")
