"""RGC end-to-end semantics on a single worker (p=1): Algorithm 4
invariants, dense-fallback dispatch, warm-up schedule, optimizer variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rgc import RGCConfig, leaf_method, rgc_apply, rgc_init
from repro.core.residual import accumulate, init_leaf, mask_communicated
from repro.core.schedule import DensitySchedule


def _params(seed=0, shape=(400, 100)):
    rng = np.random.default_rng(seed)
    return {"big": jnp.asarray(rng.standard_normal(shape), jnp.float32),
            "small": jnp.asarray(rng.standard_normal((8,)), jnp.float32)}


class TestResidualState:
    def test_accumulate_vanilla(self):
        p = jnp.zeros((10,))
        st = init_leaf(p, momentum=False)
        g = jnp.arange(10.0)
        st = accumulate(g, p, st, momentum=0.0, nesterov=False,
                        weight_decay=0.0)
        np.testing.assert_allclose(st.residual, g)
        st = accumulate(g, p, st, momentum=0.0, nesterov=False,
                        weight_decay=0.0)
        np.testing.assert_allclose(st.residual, 2 * g)

    def test_momentum_correction(self):
        """Velocity accumulates locally and both U and V are cleared at
        communicated coordinates (momentum factor masking)."""
        p = jnp.zeros((6,))
        st = init_leaf(p)
        g = jnp.ones((6,))
        st = accumulate(g, p, st, momentum=0.5, nesterov=False,
                        weight_decay=0.0)
        np.testing.assert_allclose(st.momentum, 1.0)
        np.testing.assert_allclose(st.residual, 1.0)
        st = mask_communicated(st, jnp.asarray([0, 3]), momentum=True)
        assert float(st.residual[0]) == 0 and float(st.momentum[3]) == 0
        assert float(st.residual[1]) == 1 and float(st.momentum[1]) == 1

    def test_mask_ignores_padding(self):
        p = jnp.zeros((4,))
        st = init_leaf(p)
        st = st._replace(residual=jnp.ones((4,)))
        st = mask_communicated(st, jnp.asarray([1, 4, 4]), momentum=False)
        np.testing.assert_allclose(st.residual, [1, 0, 1, 1])


class TestDispatch:
    def test_leaf_method_thresholds(self):
        cfg = RGCConfig()
        small = jnp.zeros((100,))                       # 400 B
        mid = jnp.zeros((256 * 1024,))                  # 1 MB
        big = jnp.zeros((2 * 1024 * 1024,))             # 8 MB
        assert leaf_method(small, cfg) == "dense"
        assert leaf_method(mid, cfg) == "trimmed_topk"
        assert leaf_method(big, cfg) == "threshold_binary_search"


class TestRGCApplySingleWorker:
    def test_full_density_equals_sgd(self):
        """density=1.0 sentinel: every leaf takes the dense allreduce path,
        so one step == plain momentum SGD."""
        params = _params()
        grads = jax.tree.map(lambda x: jnp.ones_like(x) * 0.5, params)
        cfg = RGCConfig(density=1.0, momentum=0.9, sync_axes=())
        st = rgc_init(params, cfg)
        new_p, _ = rgc_apply(grads, params, st, lr=jnp.float32(0.1), cfg=cfg)
        for k in params:
            np.testing.assert_allclose(
                new_p[k], params[k] - 0.1 * 0.5, rtol=1e-6)

    def test_sparse_update_touches_k_coords(self):
        params = {"w": jnp.zeros((100, 100))}
        rng = np.random.default_rng(0)
        grads = {"w": jnp.asarray(rng.standard_normal((100, 100)),
                                  jnp.float32)}
        cfg = RGCConfig(density=0.001, momentum=0.0, sync_axes=(),
                        dense_threshold_bytes=1024)
        st = rgc_init(params, cfg)
        new_p, new_st = rgc_apply(grads, params, st, lr=jnp.float32(1.0),
                                  cfg=cfg)
        changed = np.count_nonzero(np.asarray(new_p["w"]))
        k = max(1, int(np.ceil(0.001 * 10000)))
        assert changed == k
        # residual keeps the un-communicated mass
        total = np.asarray(grads["w"])
        leftover = np.asarray(new_st["w"].residual)
        sent = -np.asarray(new_p["w"])      # lr=1, p=1 => update == grad
        np.testing.assert_allclose(leftover + sent, total, atol=1e-5)

    def test_residual_eventually_flushes(self):
        """A one-shot gradient followed by zero gradients is FULLY
        communicated within ~1/density steps (no information loss — the
        core RGC correctness property), and the total applied update equals
        the original gradient exactly."""
        params = {"w": jnp.zeros((2000,))}
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.standard_normal(2000) * 0.1, jnp.float32)
        zero = jnp.zeros_like(g)
        cfg = RGCConfig(density=0.01, momentum=0.0, sync_axes=(),
                        dense_threshold_bytes=1024)
        st = rgc_init(params, cfg)
        step = jax.jit(lambda gg, pp, ss: rgc_apply(
            {"w": gg}, pp, ss, lr=jnp.float32(1.0), cfg=cfg))
        p, st = step(g, params, st)
        # k = 20/step -> 100 steps flush 2000 coords; allow slack for the
        # 2k-capacity binary-search selector's uneven batches
        for _ in range(150):
            p, st = step(zero, p, st)
        np.testing.assert_allclose(np.asarray(p["w"]), -np.asarray(g),
                                   atol=1e-6)
        assert float(jnp.max(jnp.abs(st["w"].residual))) < 1e-7

    def test_quantized_update_sign_consistent(self):
        params = {"w": jnp.zeros((60, 60))}
        rng = np.random.default_rng(2)
        grads = {"w": jnp.asarray(rng.standard_normal((60, 60)),
                                  jnp.float32)}
        cfg = RGCConfig(density=0.01, momentum=0.0, quantize=True,
                        sync_axes=(), dense_threshold_bytes=1024,
                        no_quant_paths=())
        st = rgc_init(params, cfg)
        new_p, st = rgc_apply(grads, params, st, lr=jnp.float32(1.0),
                              cfg=cfg)
        upd = -np.asarray(new_p["w"]).ravel()
        nz = upd[upd != 0]
        # phase 0: positive values selected, all set to their mean
        assert np.all(nz > 0)
        assert np.allclose(nz, nz[0])
        # next step must take the bottom-k (negative) branch
        new_p2, st = rgc_apply(grads, new_p, st, lr=jnp.float32(1.0),
                               cfg=cfg)
        upd2 = (np.asarray(new_p["w"]) - np.asarray(new_p2["w"])).ravel()
        nz2 = upd2[np.abs(upd2) > 1e-12]
        assert np.all(nz2 < 0)

    def test_bf16_residual_variant(self):
        params = _params(3)
        grads = jax.tree.map(lambda x: x * 0.01, params)
        cfg = RGCConfig(density=0.01, sync_axes=(),
                        dense_threshold_bytes=16,
                        residual_dtype=jnp.bfloat16)
        st = rgc_init(params, cfg)
        assert st["big"].residual.dtype == jnp.bfloat16
        new_p, _ = rgc_apply(grads, params, st, lr=jnp.float32(0.1), cfg=cfg)
        assert np.isfinite(np.asarray(new_p["big"])).all()


class TestSchedule:
    def test_dgc_warmup_stages(self):
        s = DensitySchedule(target=0.001, warmup_steps_per_stage=10)
        assert s.density_at(0) == 0.25
        assert s.density_at(10) == 0.0625
        assert s.density_at(39) == 0.004
        assert s.density_at(40) == 0.001

    def test_redsync_dense_warmup(self):
        s = DensitySchedule(target=0.001, warmup_steps_per_stage=5,
                            dense_warmup=True)
        assert s.density_at(0) == 1.0        # dense allreduce sentinel
        assert s.density_at(19) == 1.0
        assert s.density_at(20) == 0.001

    def test_no_warmup(self):
        s = DensitySchedule(target=0.001)
        assert s.density_at(0) == 0.001
        assert s.boundaries() == []
