"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED variant runs one forward/train step on CPU with correct output
shapes and no NaNs, plus a serve prefill+decode where the family has one."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, TrainConfig, get_config
from repro.core.rgc import rgc_init
from repro.models.registry import get_model
from repro.train.trainer import Trainer, make_rgc_config, make_train_step

ALL_ARCHS = list(ARCH_IDS) + ["paper-lstm"]


@pytest.fixture(scope="module", params=ALL_ARCHS)
def arch(request):
    return request.param


def test_smoke_config_reduced(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 5
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


def test_forward_loss_finite(arch):
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = m.init_params(0)
    batch = m.make_train_batch(2, 32)
    loss = m.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"


def test_train_step_rgc(arch):
    """One RGC train step: params change, stay finite."""
    cfg = get_config(arch, smoke=True)
    tc = TrainConfig(lr=0.1, density=0.01, optimizer="rgc")
    model = get_model(cfg)
    step = make_train_step(model, None, None, tc, donate=False)
    params = model.init_params(0)
    state = rgc_init(params, make_rgc_config(tc, None))
    batch = model.make_train_batch(2, 32)
    loss, new_p, new_s = step(params, state, batch, jnp.float32(0.1))
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(new_p):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()
    # something moved
    deltas = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(params),
                              jax.tree.leaves(new_p))]
    assert max(deltas) > 0


def test_serve_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    if m.cache_struct is None:
        pytest.skip("no decode path")
    params = m.init_params(0)
    batch = m.make_train_batch(2, 16)
    cache = m.init_cache(2, 48)
    cache, logits = m.prefill(params, batch, cache)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(3):
        logits, cache = m.decode_step(params, cache, tok, jnp.int32(16 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_short_training_reduces_loss(arch):
    """Short RGC training on learnable bigram data must reduce loss.
    (Integration: model + data + RGC optimizer end to end.)

    The learning check compares TRAILING- vs LEADING-window means of the
    per-step loss trajectory, not a single final checkpoint: single-step
    values sit on top of per-batch noise and (for MoE at smoke scale)
    the router-settling non-monotonicity of the first ~40 steps, so a
    ulp-level numeric change could flip a marginal one-step comparison
    while the trajectory is unambiguously learning. Window means are
    insensitive to both.
    """
    from repro.data import bigram_batches
    cfg = get_config(arch, smoke=True)
    # local gradient clipping (§5.6, the paper's DGC-inherited technique)
    # keeps the aggressive smoke-test lr stable on every family
    tc = TrainConfig(lr=0.5 if cfg.family == "lstm" else 0.2,
                     density=0.05, optimizer="rgc", local_clip=1.0)
    tr = Trainer(cfg, tc)
    model = tr.model
    # MoE loss is non-monotone over the first ~40 steps at smoke scale
    # (routing settles before the experts learn): give that family a
    # longer horizon so the windows straddle the settled regime
    bsz, seq, window = 8, 64, 10
    steps = 60 if cfg.family == "moe" else 30
    stub = {k: v for k, v in model.make_train_batch(bsz, seq).items()
            if k != "tokens"}

    def with_stub(src):
        for b in src:
            yield {**b, **stub}

    src = bigram_batches(cfg.vocab_size, bsz, seq, seed=2)
    train_batches = (next(src) for _ in range(steps))

    state = tr.init_state()
    losses: list[float] = []
    tr.run(state, with_stub(train_batches), steps, log_every=0,
           on_metrics=lambda step, dens, loss: losses.append(loss))
    lead = float(np.mean(losses[:window]))
    trail = float(np.mean(losses[-window:]))
    assert trail < lead, (
        f"{arch}: trailing-window loss {trail:.3f} not below "
        f"leading-window {lead:.3f} (trajectory {losses[:3]} ... "
        f"{losses[-3:]})")
