"""The Correction protocol: registry round-trip, spec grammar, hook
semantics, and equivalence of the explicit correction pipeline with the
legacy config-field-driven one (which test_api.py already holds bitwise
to the frozen monolith)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import registry
from repro.core.api import Correction
from repro.core.correction import (FactorMasking, LocalClip,
                                   MomentumCorrection, Warmup,
                                   split_corrections)
from repro.core.gradient_sync import build_gradient_sync
from repro.core.residual import init_leaf, local_clip_scale, \
    mask_communicated

CORRECTIONS = ["momentum", "factor_masking", "local_clip", "warmup"]


def _grads(shapes, seed=0):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in shapes.items()}


# ---------------------------------------------------------------------------
# registry + grammar
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_every_correction_constructible_by_name(self):
        names = registry.names(registry.CORRECTION)
        assert set(CORRECTIONS) <= set(names)
        for name in names:
            corr = registry.make(registry.CORRECTION, name)
            assert isinstance(corr, Correction)   # structural check

    def test_aliases(self):
        assert isinstance(registry.make(registry.CORRECTION, "clip"),
                          LocalClip)
        assert isinstance(registry.make(registry.CORRECTION, "masking"),
                          FactorMasking)

    def test_params_threaded(self):
        m = registry.make(registry.CORRECTION, "momentum", momentum=0.7,
                          nesterov=True, unrelated=1)
        assert m.momentum == 0.7 and m.nesterov
        c = registry.make(registry.CORRECTION, "clip", local_clip=2.5)
        assert c.clip_norm == 2.5


class TestSpecGrammar:
    @pytest.mark.parametrize("spec,corr,base", [
        ("rgc", [], "rgc"),
        ("quantized(trimmed_topk)", [], "quantized(trimmed_topk)"),
        ("momentum", ["momentum"], ""),
        ("momentum+clip(threshold_bsearch)", ["momentum", "clip"],
         "threshold_bsearch"),
        ("momentum+clip+threshold_bsearch", ["momentum", "clip"],
         "threshold_bsearch"),
        ("momentum(clip(threshold_bsearch))", ["momentum", "clip"],
         "threshold_bsearch"),
        ("warmup(rgc)", ["warmup"], "rgc"),
        ("warmup+momentum+clip(dense)", ["warmup", "momentum", "clip"],
         "dense"),
        ("momentum(quantized(trimmed_topk))", ["momentum"],
         "quantized(trimmed_topk)"),
    ])
    def test_split(self, spec, corr, base):
        assert split_corrections(spec) == (corr, base)

    @pytest.mark.parametrize("bad", [
        "nope+momentum",                 # non-correction before the base
        "clip(threshold_bsearch)+warmup",  # paren correction must be last
        "momentum+nope+rgc",
    ])
    def test_bad_specs_raise(self, bad):
        with pytest.raises(ValueError):
            split_corrections(bad)

    def test_build_rejects_unknown(self):
        with pytest.raises(ValueError):
            build_gradient_sync("momentum+nope")
        with pytest.raises(ValueError):
            build_gradient_sync("nope")


# ---------------------------------------------------------------------------
# hook semantics
# ---------------------------------------------------------------------------

class TestHooks:
    def test_momentum_correction_masks_own_velocity(self):
        """Velocity accumulates, and clears at communicated coords — the
        same semantics legacy mask_communicated(momentum=True) had."""
        p = jnp.zeros((6,))
        corr = MomentumCorrection(0.5)
        st = init_leaf(p, momentum=True)
        st = corr.accumulate(jnp.ones((6,)), p, st, weight_decay=0.0)
        np.testing.assert_allclose(st.momentum, 1.0)
        np.testing.assert_allclose(st.residual, 1.0)
        idx = jnp.asarray([0, 3, 6])       # 6 == size: padding sentinel
        legacy = mask_communicated(st, idx, momentum=True)
        new = mask_communicated(st, idx, momentum=False)
        new = corr.on_communicated(new, idx)
        np.testing.assert_array_equal(np.asarray(legacy.residual),
                                      np.asarray(new.residual))
        np.testing.assert_array_equal(np.asarray(legacy.momentum),
                                      np.asarray(new.momentum))
        assert float(new.momentum[0]) == 0 and float(new.momentum[1]) == 1

    def test_factor_masking_noop_on_scalar_velocity(self):
        st = init_leaf(jnp.zeros((4,)), momentum=False)
        out = FactorMasking().on_communicated(st, jnp.asarray([0, 1]))
        assert out.momentum.shape == ()     # untouched scalar placeholder

    def test_local_clip_matches_reference_formula(self):
        grads = list(_grads({"a": (32,), "b": (7,)}).values())
        clip = LocalClip(1.0)
        out = clip.on_grads(grads, grads, num_workers=4)
        sq = sum(float(jnp.sum(g ** 2)) for g in grads)
        scale = float(local_clip_scale(jnp.float32(sq), 1.0, 4))
        for g, o in zip(grads, out):
            np.testing.assert_allclose(np.asarray(o), np.asarray(g) * scale,
                                       rtol=1e-6)

    def test_warmup_owns_schedule(self):
        w = registry.make(registry.CORRECTION, "warmup", density=0.01,
                          warmup_steps_per_stage=5, dense_warmup=True)
        assert w.density_at(0, 0.01) == 1.0
        assert w.density_at(19, 0.01) == 1.0
        assert w.density_at(20, 0.01) == 0.01

    def test_warmup_defaults_to_real_ramp_when_unset(self):
        """A spec that NAMES warmup gets an actual ramp even when the
        config leaves warmup_steps_per_stage at 0."""
        w = registry.make(registry.CORRECTION, "warmup", density=0.001)
        assert w.density_at(0, 0.001) == 0.25
        assert w.schedule.warmup_steps_per_stage == \
            Warmup.DEFAULT_STEPS_PER_STAGE


# ---------------------------------------------------------------------------
# GradientSync integration
# ---------------------------------------------------------------------------

class TestGradientSyncIntegration:
    SHAPES = {"w": (400, 50), "b": (16,)}

    def test_explicit_spec_matches_implicit_fields_bitwise(self):
        """"momentum+clip(threshold_bsearch)" == "threshold_bsearch" with
        the momentum/local_clip config fields — the corrections ARE the
        legacy behavior, made addressable."""
        kw = dict(density=0.02, momentum=0.9, nesterov=True,
                  local_clip=1.0, weight_decay=1e-4,
                  dense_threshold_bytes=32)
        explicit = build_gradient_sync("momentum+clip(threshold_bsearch)",
                                       **kw)
        implicit = build_gradient_sync("threshold_bsearch", **kw)
        params = _grads(self.SHAPES, seed=1)
        se, si = explicit.init(params), implicit.init(params)
        pe = pi = params
        for step in range(3):
            g = _grads(self.SHAPES, seed=10 + step)
            pe, se = explicit.update(g, se, pe, jnp.float32(0.1))
            pi, si = implicit.update(g, si, pi, jnp.float32(0.1))
        for a, b in zip(jax.tree.leaves((pe, se)), jax.tree.leaves((pi, si))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_spec_corrections_are_additive_with_config_fields(self):
        """The momentum/local_clip FIELDS are the on/off switches: a spec
        naming only 'clip' still gets momentum correction from
        momentum=0.9 (sparse leaves must stay consistent with the
        dense-leaf momentum SGD the same field drives); ablation is
        momentum=0.0, not omission."""
        sync = build_gradient_sync("clip(threshold_bsearch)", momentum=0.9,
                                   local_clip=1.0)
        assert [c.name for c in sync.corrections] == ["local_clip",
                                                      "momentum"]
        ablated = build_gradient_sync("clip(threshold_bsearch)",
                                      momentum=0.0, local_clip=1.0)
        assert [c.name for c in ablated.corrections] == ["local_clip"]

    def test_warmup_spec_keeps_momentum_correction(self):
        """"warmup(rgc)" == "rgc" + the density ramp — switching the spec
        must not silently drop momentum correction on sparse leaves."""
        plain = build_gradient_sync("rgc", momentum=0.9, local_clip=1.0)
        ramped = build_gradient_sync("warmup(rgc)", momentum=0.9,
                                     local_clip=1.0, density=0.02,
                                     warmup_steps_per_stage=2)
        assert ({c.name for c in plain.corrections} ==
                {c.name for c in ramped.corrections} - {"warmup"})
        params = _grads(self.SHAPES, seed=2)
        sp, sr = plain.init(params), ramped.init(params)
        pp = pr = params
        for step in range(2):   # identical at equal density
            g = _grads(self.SHAPES, seed=20 + step)
            pp, sp = plain.update(g, sp, pp, jnp.float32(0.1), density=0.02)
            pr, sr = ramped.update(g, sr, pr, jnp.float32(0.1), density=0.02)
        for a, b in zip(jax.tree.leaves((pp, sp)), jax.tree.leaves((pr, sr))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corrections_only_spec_defaults_to_rgc(self):
        sync = build_gradient_sync("momentum+clip", local_clip=1.0)
        assert [c.name for c in sync.corrections] == ["momentum",
                                                      "local_clip"]
        assert type(sync.policy).__name__ == "SizeBasedPolicy"

    def test_scheduled_density(self):
        sync = build_gradient_sync("warmup+momentum(rgc)", density=0.01,
                                   warmup_steps_per_stage=2,
                                   dense_warmup=True)
        assert sync.scheduled_density(0) == 1.0
        assert sync.scheduled_density(8) == 0.01
        nosched = build_gradient_sync("rgc")
        assert nosched.scheduled_density(0) is None

    def test_warmup_spec_drives_trainer_schedule(self):
        from repro.configs import TrainConfig, get_config
        from repro.train.trainer import Trainer
        cfg = get_config("internlm2-1.8b", smoke=True)
        tc = TrainConfig(optimizer="warmup+momentum+clip(threshold_bsearch)",
                         density=0.01, local_clip=1.0,
                         warmup_steps_per_stage=2, dense_warmup=True)
        tr = Trainer(cfg, tc)
        assert tr.density_at(0) == 1.0
        assert tr.density_at(7) == 1.0
        assert tr.density_at(8) == 0.01

    def test_momentum_spec_trains_finite(self):
        from repro.configs import TrainConfig, get_config
        from repro.data import bigram_batches
        from repro.train.trainer import Trainer
        cfg = get_config("internlm2-1.8b", smoke=True)
        tc = TrainConfig(lr=0.1, momentum=0.9, local_clip=1.0, density=0.02,
                         optimizer="momentum+clip(threshold_bsearch)")
        tr = Trainer(cfg, tc)
        state = tr.run(tr.init_state(),
                       bigram_batches(cfg.vocab_size, 2, 32, seed=0),
                       3, log_every=0)
        assert state.step == 3
        for leaf in jax.tree.leaves(state.params):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()
