"""Serving correctness: prefill+decode must agree with the full forward
(teacher forcing), SWA ring-buffer semantics, ServeLoop driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.train.serve import ServeLoop


def _greedy_from_loss_forward(m, params, tokens, steps):
    """Oracle: recompute the FULL forward at every decode step."""
    toks = tokens
    out = []
    for _ in range(steps):
        cache = m.init_cache(toks.shape[0], toks.shape[1] + 1)
        _, logits = m.prefill(params, {"tokens": toks}, cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(nxt)
        toks = jnp.concatenate([toks, nxt], axis=1)
    return jnp.concatenate(out, axis=1)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-3b",
                                  "recurrentgemma-9b", "h2o-danube-3-4b"])
def test_incremental_decode_matches_recompute(arch):
    """KV-cache/state decode == full recompute (the cache is exact)."""
    cfg = get_config(arch, smoke=True)
    m = get_model(cfg)
    params = m.init_params(0)
    prompt = m.make_train_batch(2, 12)["tokens"]

    # incremental
    cache = m.init_cache(2, 12 + 5)
    cache, logits = m.prefill(params, {"tokens": prompt}, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    inc = [tok]
    for i in range(4):
        logits, cache = m.decode_step(params, cache, tok, jnp.int32(12 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        inc.append(tok)
    inc = jnp.concatenate(inc, axis=1)

    ref = _greedy_from_loss_forward(m, params, prompt, 5)
    np.testing.assert_array_equal(np.asarray(inc), np.asarray(ref))


def test_swa_ring_buffer_matches_full_when_window_covers():
    """A window >= total length must reproduce full attention exactly."""
    base = dict(name="t", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=128, dtype=jnp.float32, scan_layers=False,
                attn_q_chunk=8, attn_kv_chunk=8, loss_chunk=16)
    cfg_full = ModelConfig(**base)
    cfg_swa = ModelConfig(**{**base, "window_size": 64})
    mf, ms = get_model(cfg_full), get_model(cfg_swa)
    params = mf.init_params(0)       # identical param trees

    prompt = mf.make_train_batch(2, 10)["tokens"]
    outs = []
    for m in (mf, ms):
        cache = m.init_cache(2, 32)
        cache, logits = m.prefill(params, {"tokens": prompt}, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        seq = [tok]
        for i in range(4):
            logits, cache = m.decode_step(params, cache, tok,
                                          jnp.int32(10 + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            seq.append(tok)
        outs.append(np.asarray(jnp.concatenate(seq, axis=1)))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_swa_ring_decode_beyond_window():
    """Decode far past the window: ring buffer stays consistent (finite,
    and only in-window positions attended)."""
    cfg = get_config("h2o-danube-3-4b", smoke=True)   # window 16 in smoke
    m = get_model(cfg)
    params = m.init_params(0)
    prompt = m.make_train_batch(1, 8)["tokens"]
    cache = m.init_cache(1, 64)
    cache, logits = m.prefill(params, {"tokens": prompt}, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(40):               # 8 + 40 >> window 16
        logits, cache = m.decode_step(params, cache, tok, jnp.int32(8 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_serve_loop_driver():
    cfg = get_config("internlm2-1.8b", smoke=True)
    m = get_model(cfg)
    params = m.init_params(0)
    sl = ServeLoop(m, batch=2, max_len=32)
    toks = sl.generate(params, m.make_train_batch(2, 8), 6)
    assert toks.shape == (2, 6)
    assert np.all((np.asarray(toks) >= 0)
                  & (np.asarray(toks) < cfg.vocab_size))


def test_whisper_serve_cross_attention_cache():
    cfg = get_config("whisper-large-v3", smoke=True)
    m = get_model(cfg)
    params = m.init_params(0)
    b = m.make_train_batch(2, 8)
    cache = m.init_cache(2, 16)
    cache, logits = m.prefill(params, b, cache)
    # cross-KV must be populated (non-zero) after prefill
    assert float(jnp.max(jnp.abs(cache[0]["xk"]))) > 0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits, cache = m.decode_step(params, cache, tok, jnp.int32(8))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
