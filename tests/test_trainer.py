"""Trainer-level behaviour: warm-up schedule staging, checkpoint output,
optimizer-variant parity of the public API."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore
from repro.configs import TrainConfig, get_config
from repro.data import bigram_batches
from repro.train.trainer import Trainer


def test_warmup_schedule_stages_and_recompiles():
    cfg = get_config("internlm2-1.8b", smoke=True)
    tc = TrainConfig(lr=0.2, density=0.01, optimizer="rgc",
                     warmup_steps_per_stage=2, dense_warmup=True)
    tr = Trainer(cfg, tc)
    state = tr.init_state()
    seen = []
    orig = tr._step_fn

    def spy(density):
        seen.append(density)
        return orig(density)

    tr._step_fn = spy
    state = tr.run(state, bigram_batches(cfg.vocab_size, 2, 32, seed=0),
                   10, log_every=0)
    # steps 0..7 dense warm-up (4 stages x 2), then target density
    assert seen[:8] == [1.0] * 8
    assert seen[8:] == [0.01, 0.01]
    assert len(tr._steps) == 2          # two compilations: dense + target


def test_trainer_checkpoint(tmp_path):
    cfg = get_config("internlm2-1.8b", smoke=True)
    tc = TrainConfig(lr=0.2, density=0.01, optimizer="rgc")
    tr = Trainer(cfg, tc, ckpt_dir=str(tmp_path))
    state = tr.init_state()
    state = tr.run(state, bigram_batches(cfg.vocab_size, 2, 32, seed=0),
                   3, log_every=0)
    assert latest_step(str(tmp_path)) == 3
    restored = restore(str(tmp_path), state.params)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dense_vs_rgc_public_api_parity():
    """Same seed + full-density RGC == dense optimizer, end to end."""
    cfg = get_config("internlm2-1.8b", smoke=True)
    outs = {}
    for opt in ("dense", "rgc"):
        tc = TrainConfig(lr=0.2, momentum=0.9, optimizer=opt,
                         density=1.0, seed=3)
        tr = Trainer(cfg, tc)
        st = tr.init_state()
        st = tr.run(st, bigram_batches(cfg.vocab_size, 2, 32, seed=3), 3,
                    log_every=0)
        outs[opt] = st.params
    for a, b in zip(jax.tree.leaves(outs["dense"]),
                    jax.tree.leaves(outs["rgc"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
