"""Multi-device correctness program, run as a subprocess by
test_distributed.py with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag must be set before jax init, so it cannot run inside the main
pytest process).

Checks, on a (4 data x 2 model) mesh:
 1. sparse sync equivalence — RGC at density 1.0 (dense sentinel) matches
    single-device SGD on the concatenated global batch, bitwise-ish.
 2. RGC sparse update correctness — the multi-worker sparse allgather sum
    equals an oracle computed from each worker's local top-k.
 3. quantized + momentum variants run and stay finite.
"""
import sys

from harness.cluster import check, force_host_devices

force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.core.rgc import RGCConfig, rgc_apply, rgc_init
from repro.core import selection as sel
from repro.data import bigram_batches
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer, make_rgc_config, make_train_step
from repro.models.registry import get_model


def test_dense_equivalence():
    """density=1.0 multi-worker == single-device big-batch SGD."""
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = get_model(cfg)
    tc = TrainConfig(lr=0.1, momentum=0.9, optimizer="dense")
    mesh = make_host_mesh(4, 2)

    params = model.init_params(0)
    batch = model.make_train_batch(8, 32)

    # multi-device
    step = make_train_step(model, mesh, None, tc, donate=False)
    st = rgc_init(params, make_rgc_config(tc, mesh))
    loss_m, p_m, _ = step(params, st, batch, jnp.float32(0.1))

    # single device oracle
    step1 = make_train_step(model, None, None, tc, donate=False)
    st1 = rgc_init(params, make_rgc_config(tc, None))
    loss_1, p_1, _ = step1(params, st1, batch, jnp.float32(0.1))

    check("dense loss match",
          abs(float(loss_m) - float(loss_1)) < 1e-4 * max(1, abs(float(loss_1))))
    errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p_m), jax.tree.leaves(p_1))]
    check(f"dense params match (max err {max(errs):.2e})", max(errs) < 5e-3)


def test_sparse_allgather_oracle():
    """Each of the 4 data workers compresses a DIFFERENT local gradient;
    the decompressed sum must equal the sum of each worker's top-k
    contribution (computed with the pure selector as oracle)."""
    mesh = make_host_mesh(4, 1)
    n, k_density = 4000, 0.01
    rng = np.random.default_rng(0)
    grads_per_worker = rng.standard_normal((4, n)).astype(np.float32)
    params = jnp.zeros((n,), jnp.float32)
    cfg = RGCConfig(density=k_density, momentum=0.0, sync_axes=("data",),
                    dense_threshold_bytes=64)

    from jax.sharding import PartitionSpec as P

    def worker(g, p, st):
        new_p, new_st = rgc_apply({"w": g}, {"w": p}, {"w": st},
                                  lr=jnp.float32(1.0), cfg=cfg)
        return new_p["w"], new_st["w"]

    st0 = rgc_init({"w": params}, cfg)["w"]
    from repro.jaxcompat import shard_map as shard_map_compat
    f = jax.jit(shard_map_compat(
        worker, mesh=mesh,
        in_specs=(P("data"), P(), jax.tree.map(lambda _: P(), st0)),
        out_specs=(P(), jax.tree.map(lambda _: P(), st0)),
        check_vma=False))
    new_p, _ = f(jnp.asarray(grads_per_worker), params, st0)

    # oracle: sum of each worker's selected top-k, averaged over 4
    k = max(1, int(np.ceil(k_density * n)))
    expect = np.zeros(n, np.float32)
    for w in range(4):
        s = sel.trimmed_topk(jnp.asarray(grads_per_worker[w]), k)
        cnt = int(s.count)
        np.add.at(expect, np.asarray(s.indices)[:cnt],
                  np.asarray(s.values)[:cnt])
    expect /= 4.0
    err = np.max(np.abs(np.asarray(new_p) + expect))   # lr=1 -> p = -upd
    check(f"sparse allgather oracle (err {err:.2e})", err < 1e-5)


def test_variants_run():
    mesh = make_host_mesh(4, 2)
    cfg = get_config("granite-moe-3b-a800m", smoke=True)
    for opt in ("rgc", "rgc_quant"):
        tc = TrainConfig(lr=0.2, density=0.02, optimizer=opt,
                         local_clip=1.0)
        tr = Trainer(cfg, tc, mesh=mesh)
        st = tr.init_state()
        st = tr.run(st, bigram_batches(cfg.vocab_size, 8, 32, seed=0), 5,
                    log_every=0)
        finite = all(np.isfinite(np.asarray(l, np.float32)).all()
                     for l in jax.tree.leaves(st.params))
        check(f"{opt} 5 steps finite on mesh", finite)


def test_multipod_axes():
    """3-axis mesh ('pod','data','model'): RGC syncs over ('pod','data')."""
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = get_config("internlm2-1.8b", smoke=True)
    tc = TrainConfig(lr=0.2, density=0.02, optimizer="rgc")
    tr = Trainer(cfg, tc, mesh=mesh)
    st = tr.init_state()
    st = tr.run(st, bigram_batches(cfg.vocab_size, 8, 32, seed=0), 3,
                log_every=0)
    finite = all(np.isfinite(np.asarray(l, np.float32)).all()
                 for l in jax.tree.leaves(st.params))
    check("multi-pod axes RGC finite", finite)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    fns = {"dense": test_dense_equivalence,
           "oracle": test_sparse_allgather_oracle,
           "variants": test_variants_run,
           "multipod": test_multipod_axes}
    if which == "all":
        for f in fns.values():
            f()
    else:
        fns[which]()
    print("OK")
