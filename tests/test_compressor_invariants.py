"""Deterministic invariants for EVERY registered compressor.

The same invariants are stressed with randomized shapes under hypothesis
in tests/test_property.py (skipped where hypothesis isn't installed);
this file pins them on a fixed grid so every environment runs them:

  * exact mass conservation — ``decompress(msg) + residual == grad``
    bitwise per-coordinate for non-quantized selectors (the communicated
    coordinates carry the exact residual values; the rest stays);
    sum-conservation within fp tolerance for quantized ones.
  * ``count <= capacity`` and index validity/padding.
  * bf16/f32 residual + param dtype preservation through the pipeline.
  * determinism under ``jit`` (two jitted calls and eager agree bitwise).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import registry
from repro.core.gradient_sync import build_gradient_sync
from repro.core.residual import mask_communicated

SIZES = [(64, 3), (512, 16), (1000, 7)]


def _selecting_names():
    return sorted(n for n in registry.names(registry.COMPRESSOR)
                  if n != "dense")


def _vec(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n), jnp.float32)


def _compress_roundtrip(comp, x, k):
    tr = registry.make(registry.TRANSPORT, "fused_allgather", sync_axes=())
    st = comp.init_leaf(x, momentum=False)
    st = st._replace(residual=x)
    sel, st = comp.compress(x, k, st)
    st = mask_communicated(st, sel.indices, momentum=False)
    (gathered,) = tr.allgather([tr.pack(sel, comp.quantized)])
    dense = comp.decompress(gathered, x.size, k)
    return sel, st.residual, dense


@pytest.mark.parametrize("name", _selecting_names())
@pytest.mark.parametrize("n,k", SIZES)
def test_mass_conservation(name, n, k):
    comp = registry.make(registry.COMPRESSOR, name)
    x = _vec(n, seed=n + k)
    sel, residual, dense = _compress_roundtrip(comp, x, k)
    if comp.quantized:
        # quantized messages carry one shared magnitude: per-coordinate
        # exactness is lost, total communicated mass is conserved
        np.testing.assert_allclose(
            float(jnp.sum(dense)),
            float(jnp.sum(sel.values)), rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_array_equal(
            np.asarray(residual + dense), np.asarray(x),
            err_msg=f"{name}: residual + decompressed != grad")


@pytest.mark.parametrize("name", _selecting_names())
@pytest.mark.parametrize("n,k", SIZES)
def test_count_capacity_and_padding(name, n, k):
    comp = registry.make(registry.COMPRESSOR, name)
    x = _vec(n, seed=n * 31 + k)
    sel, _, _ = _compress_roundtrip(comp, x, k)
    cap = comp.capacity(k)
    cnt = int(sel.count)
    idx = np.asarray(sel.indices)
    assert 1 <= cnt <= cap
    assert idx.shape == (cap,)
    assert np.all((idx[:cnt] >= 0) & (idx[:cnt] < n))
    assert np.all(idx[cnt:] == n)          # padding carries the sentinel


@pytest.mark.parametrize("name", _selecting_names())
@pytest.mark.parametrize("residual_dtype", [jnp.float32, jnp.bfloat16])
def test_leaf_state_dtype_preserved(name, residual_dtype):
    comp = registry.make(registry.COMPRESSOR, name)
    x = _vec(256, seed=11)
    st = comp.init_leaf(x, momentum=True, residual_dtype=residual_dtype)
    assert st.residual.dtype == residual_dtype
    sel, st2 = comp.compress(st.residual.astype(jnp.float32), 8, st)
    st2 = mask_communicated(st2, sel.indices, momentum=True)
    assert st2.residual.dtype == residual_dtype
    assert st2.momentum.dtype == jnp.float32


@pytest.mark.parametrize("param_dtype", [jnp.float32, jnp.bfloat16])
def test_gradient_sync_preserves_param_dtype(param_dtype):
    sync = build_gradient_sync("threshold_bsearch", density=0.02)
    params = {"w": _vec(400, seed=1).astype(param_dtype),
              "b": _vec(8, seed=2).astype(param_dtype)}
    grads = {"w": _vec(400, seed=3).astype(param_dtype),
             "b": _vec(8, seed=4).astype(param_dtype)}
    st = sync.init(params)
    new_p, new_s = sync.update(grads, st, params, jnp.float32(0.1))
    for key in params:
        assert new_p[key].dtype == param_dtype
        assert np.isfinite(np.asarray(new_p[key], np.float32)).all()


@pytest.mark.parametrize("name", _selecting_names())
def test_deterministic_under_jit(name):
    comp = registry.make(registry.COMPRESSOR, name)
    n, k = 600, 9
    x = _vec(n, seed=77)
    st0 = comp.init_leaf(x, momentum=False)

    def f(v, st):
        sel, st2 = comp.compress(v, k, st)
        return sel.indices, sel.values, sel.count, st2

    jitted = jax.jit(f)
    a, b = jitted(x, st0), jitted(x, st0)
    eager = f(x, st0)
    for got1, got2, ref in zip(a[:3], b[:3], eager[:3]):
        np.testing.assert_array_equal(np.asarray(got1), np.asarray(got2))
        np.testing.assert_array_equal(np.asarray(got1), np.asarray(ref))


def test_dense_compressor_never_compresses():
    """'dense' is the allreduce sentinel: compress is a contract error."""
    comp = registry.make(registry.COMPRESSOR, "dense")
    assert comp.capacity(8) == 0
    with pytest.raises(NotImplementedError):
        comp.compress(_vec(16), 4, comp.init_leaf(_vec(16), momentum=False))
