"""Small-mesh dry-run integration check, run as a subprocess (needs its own
XLA device-count flag). Lowers + compiles the REAL dryrun code paths
(train RGC step, prefill, decode) for smoke configs on a 4x2 mesh and
checks cost/collective extraction works end to end."""
from harness.cluster import force_host_devices

force_host_devices(8)

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.configs.shapes import InputShape
from repro.launch.hlo_stats import collective_summary
from repro.launch.mesh import make_host_mesh
from repro.launch import dryrun as dr


def main() -> None:
    mesh = make_host_mesh(4, 2)
    shape_train = InputShape("t", 64, 8, "train")
    shape_dec = InputShape("d", 64, 8, "decode")
    for arch in ("internlm2-1.8b", "granite-moe-3b-a800m", "rwkv6-3b"):
        cfg = get_config(arch, smoke=True)
        for shape in (shape_train, shape_dec):
            lowered, meta = dr.lower_pair(arch, shape, mesh, cfg=cfg)
            assert lowered is not None, (arch, shape.kind)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            assert cost.get("flops", 0) > 0, (arch, shape.kind)
            summ = collective_summary(compiled.as_text())
            if shape.kind == "train":
                # RGC sparse sync must emit at least one all-gather
                assert "all-gather" in summ["by_op"], (arch, summ["by_op"])
            print(f"PASS {arch} {shape.kind} "
                  f"wire={summ['total_wire_bytes']}")
    print("OK")


if __name__ == "__main__":
    main()
