"""Frozen copy of the pre-decomposition ``rgc_apply`` monolith.

This is the seed's fused Algorithm 4 + 5 implementation, kept verbatim as
the reference for the bitwise parity test in tests/test_api.py: the
composed ``GradientSync`` pipeline must reproduce it exactly (params AND
state) on every dispatch path. Do not "fix" or modernize this file — its
value is being frozen. (It retains the seed's 4-bytes-per-element
dispatch assumption, so parity is asserted on f32 models where that
matches the real itemsize.)
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import selection as sel_lib
from repro.core import sync as sync_lib
from repro.core.cost_model import choose_method
from repro.core.residual import (LeafState, accumulate, init_leaf,
                                 local_clip_scale, mask_communicated)
from repro.core.rgc import RGCConfig


def leaf_bytes(x: jax.Array) -> int:
    return x.size * 4  # the seed's assumption: f32 everywhere


def leaf_method(x: jax.Array, cfg: RGCConfig) -> str:
    return choose_method(
        leaf_bytes(x), cfg.dense_threshold_bytes, cfg.trimmed_threshold_bytes
    )


def legacy_rgc_init(params: Any, cfg: RGCConfig | None = None) -> Any:
    cfg = cfg or RGCConfig()
    return jax.tree.map(
        lambda p: init_leaf(p, momentum=bool(cfg.momentum),
                            residual_dtype=cfg.residual_dtype), params)


def _select(flat_v: jax.Array, k: int, method: str, state: LeafState,
            cfg: RGCConfig, quantize: bool):
    """Run the statically chosen selector. Returns (Selected, new LeafState)."""
    if cfg.backend == "pallas":
        from repro.kernels import ops as kops
        if method == "trimmed_topk" and not quantize:
            return kops.trimmed_topk(flat_v, k), state
        if method == "threshold_binary_search" and not quantize:
            selected, thr = kops.threshold_binary_search(flat_v, k)
            return selected, state._replace(threshold=thr)
    if quantize:
        if method == "trimmed_topk":
            s = sel_lib.trimmed_topk_quant(flat_v, k, state.phase)
        else:
            s = sel_lib.threshold_binary_search_quant(flat_v, k, state.phase)
        return s, state._replace(phase=(state.phase + 1) % 2)
    if method == "trimmed_topk":
        return sel_lib.trimmed_topk(flat_v, k), state
    # sampled threshold binary search with threshold reuse (interval = 5)
    def refresh(_):
        s, thr = sel_lib.threshold_binary_search(flat_v, k)
        return s, thr
    def reuse(_):
        s = sel_lib.threshold_filter(flat_v, state.threshold, capacity=2 * k)
        return s, state.threshold
    do_refresh = (state.interval % cfg.bsearch_interval) == 0
    s, thr = jax.lax.cond(do_refresh, refresh, reuse, operand=None)
    return s, state._replace(threshold=thr, interval=state.interval + 1)


def _capacity(k: int, method: str) -> int:
    return k if method == "trimmed_topk" else 2 * k


def legacy_rgc_apply(
    grads: Any,
    params: Any,
    state: Any,
    *,
    lr: jax.Array,
    cfg: RGCConfig,
    density: float | None = None,
) -> tuple[Any, Any]:
    """One synchronized RGC update (the seed's fused monolith)."""
    density = cfg.density if density is None else density
    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_p = treedef.flatten_up_to(params)
    leaves_s = treedef.flatten_up_to(state)
    paths = [jax.tree_util.keystr(kp)
             for kp, _ in jax.tree_util.tree_flatten_with_path(grads)[0]]
    n_workers = 1
    for ax in cfg.sync_axes:
        n_workers *= jax.lax.axis_size(ax)

    # --- optional DGC local clipping (pre-accumulation, N^{-1/2}) ----------
    if cfg.local_clip is not None:
        sq = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves_g)
        scale = local_clip_scale(sq, cfg.local_clip, n_workers)
        leaves_g = [g * scale for g in leaves_g]

    # density == 1.0 sentinel: RedSync dense warm-up (§5.7) — everything dense
    all_dense = density >= 1.0

    plan = []  # (i, method, k, cap, quantize)
    for i, (g, p) in enumerate(zip(leaves_g, leaves_p)):
        method = "dense" if all_dense else leaf_method(g, cfg)
        if method == "dense":
            plan.append((i, "dense", 0, 0, False))
            continue
        k = max(1, int(math.ceil(density * g.size)))
        quant = cfg.quantize and not any(t in paths[i] for t in cfg.no_quant_paths)
        plan.append((i, method, k, _capacity(k, method), quant))

    # --- pass 1: residual update + selection + message packing -------------
    messages: list[jax.Array] = []
    msg_meta: list[tuple[int, int, bool]] = []   # (leaf index, cap, quant)
    new_states: list[LeafState] = list(leaves_s)
    for i, method, k, cap, quant in plan:
        if method == "dense":
            continue
        st = accumulate(
            leaves_g[i], leaves_p[i], leaves_s[i],
            momentum=cfg.momentum, nesterov=cfg.nesterov,
            weight_decay=cfg.weight_decay,
        )
        flat_v = st.residual.reshape(-1).astype(jnp.float32)
        selected, st = _select(flat_v, k, method, st, cfg, quant)
        st = mask_communicated(st, selected.indices, momentum=bool(cfg.momentum))
        new_states[i] = st
        messages.append(sync_lib.pack(selected, quant))
        msg_meta.append((i, cap, quant))

    # --- pass 2: synchronization -------------------------------------------
    if messages:
        if cfg.fuse_messages:
            gathered = sync_lib.fused_allgather(messages, cfg.sync_axes)
        else:
            gathered = [sync_lib.sparse_allgather(m, cfg.sync_axes)
                        for m in messages]
    else:
        gathered = []

    # --- pass 3: decompress + apply ----------------------------------------
    new_params: list[jax.Array] = list(leaves_p)
    for buf, (i, cap, quant) in zip(gathered, msg_meta):
        g_sum = sync_lib.unpack_decompress(buf, leaves_p[i].size, cap, quant)
        upd = (g_sum / n_workers).reshape(leaves_p[i].shape)
        new_params[i] = (leaves_p[i].astype(jnp.float32)
                         - lr * upd).astype(leaves_p[i].dtype)

    for i, method, k, cap, quant in plan:
        if method != "dense":
            continue
        g_mean = sync_lib.dense_allreduce_mean(leaves_g[i], cfg.sync_axes)
        st = leaves_s[i]
        if cfg.weight_decay:
            g_mean = g_mean + cfg.weight_decay * leaves_p[i].astype(jnp.float32)
        if cfg.momentum:
            u = cfg.momentum * st.momentum + g_mean
            upd = (g_mean + cfg.momentum * u) if cfg.nesterov else u
            new_states[i] = st._replace(momentum=u)
        else:
            upd = g_mean
        new_params[i] = (leaves_p[i].astype(jnp.float32)
                         - lr * upd).astype(leaves_p[i].dtype)

    return (jax.tree.unflatten(treedef, new_params),
            jax.tree.unflatten(treedef, new_states))
