"""Simulated-cluster harness: an N-way worker mesh on forced host devices.

Entry points (see ``cluster.py``):

* ``force_host_devices(n)`` — set the XLA flag that splits the host CPU
  into ``n`` devices (must run before jax initializes).
* ``make_data_mesh()`` — 1-D ``("data",)`` mesh over every visible device;
  the trainer takes its fully-manual pure-data-parallel path on it.
* ``make_node_mesh(nodes)`` — 2-axis ``("node", "local")`` mesh (the
  simulated multi-node cluster the ``hierarchical`` transport syncs over).
* ``train_and_eval(...)`` — a real short training run through
  ``repro.train.trainer.Trainer`` on that mesh + held-out loss.
* ``run_cluster(spec)`` — the subprocess driver (device forcing must
  happen before jax init, so multi-device runs go through
  ``_cluster_prog.py`` in a child process).
* ``convergence_pair(...)`` — sparse-with-corrections vs dense baseline
  on the same mesh/budget; what the tier-2 tests and
  ``benchmarks/tab1_convergence.py`` consume.
"""
from .cluster import (CLUSTER_PROG, check, convergence_pair,
                      force_host_devices, make_data_mesh, make_node_mesh,
                      run_cluster, subprocess_env, train_and_eval)

__all__ = ["CLUSTER_PROG", "check", "convergence_pair",
           "force_host_devices", "make_data_mesh", "make_node_mesh",
           "run_cluster", "subprocess_env", "train_and_eval"]
