"""Subprocess entry point for the simulated-cluster harness.

Usage: ``python _cluster_prog.py '<json>'`` where the JSON is
``{"devices": N, "run": {train_and_eval kwargs}}``. Forces N host devices
BEFORE jax initializes, runs the training loop on the ``("data",)`` mesh,
and prints ``RESULT <json>`` for the parent (``cluster.run_cluster``).
"""
import json
import sys

from harness.cluster import check, force_host_devices, train_and_eval


def main() -> None:
    spec = json.loads(sys.argv[1])
    force_host_devices(spec.get("devices", 8))

    import jax  # first jax touch happens after the flag is set
    n = len(jax.devices())
    check(f"forced {spec.get('devices', 8)} host devices (got {n})",
          n == spec.get("devices", 8))

    out = train_and_eval(**spec["run"])
    print("RESULT " + json.dumps(out))
    print("OK")


if __name__ == "__main__":
    main()
