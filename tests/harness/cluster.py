"""Simulated-cluster machinery (no jax import at module scope).

The harness splits one host CPU into N XLA devices
(``--xla_force_host_platform_device_count``), builds a pure data-parallel
mesh over them — flat ``("data",)`` or, for the hierarchical transport,
2-axis ``("node", "local")`` — and drives real training loops through
``repro.train.trainer.Trainer`` — the trainer's fully-manual shard_map
path, which runs on both legacy (0.4.x) and modern jax. Each worker sees
its own batch shard and computes LOCAL gradients, so the residual /
correction / selection / allgather pipeline is exercised exactly as on a
real cluster (p = N in Eq 1), just without the wire.

Device forcing must happen before jax initializes, so multi-device runs
from an already-jax-initialized process (pytest, benchmarks) go through
``run_cluster`` → ``_cluster_prog.py`` in a subprocess; in-process use
(``train_and_eval``) is for programs that called ``force_host_devices``
first.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Any

TESTS_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REPO_ROOT = os.path.dirname(TESTS_DIR)
SRC_DIR = os.path.join(REPO_ROOT, "src")
CLUSTER_PROG = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "_cluster_prog.py")

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def force_host_devices(n: int = 8) -> None:
    """Split the host platform into ``n`` XLA devices.

    Only effective before jax initializes its backends — call it at the
    top of a standalone program, before any jax import.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if _FORCE_FLAG in flags:
        flags = " ".join(f for f in flags.split()
                         if not f.startswith(_FORCE_FLAG))
    os.environ["XLA_FLAGS"] = f"{flags} {_FORCE_FLAG}={n}".strip()


def check(name: str, cond: bool) -> None:
    """Subprocess-program assertion: PASS/FAIL line + nonzero exit."""
    print(("PASS" if cond else "FAIL"), name)
    if not cond:
        sys.exit(1)


def subprocess_env(extra: dict[str, str] | None = None) -> dict[str, str]:
    """Environment for harness/test subprocesses: repo src + tests on path."""
    env = dict(os.environ)
    path = [SRC_DIR, TESTS_DIR]
    if env.get("PYTHONPATH"):
        path.append(env["PYTHONPATH"])
    env["PYTHONPATH"] = os.pathsep.join(path)
    env.update(extra or {})
    return env


def make_data_mesh(num_devices: int | None = None):
    """1-D ``("data",)`` mesh over the (forced) host devices."""
    import jax

    from repro.launch.mesh import _make_mesh
    n = len(jax.devices()) if num_devices is None else num_devices
    return _make_mesh((n,), ("data",))


def make_node_mesh(nodes: int = 2, local: int | None = None):
    """2-axis ``("node", "local")`` mesh over the forced host devices —
    the simulated multi-node cluster the ``hierarchical`` transport syncs
    over (inter-node sparse allgather on "node", intra-node dense psum on
    "local"). ``local=None`` uses all remaining devices per node."""
    import jax

    from repro.launch.mesh import _make_mesh
    n = len(jax.devices())
    if local is None:
        if n % nodes:
            raise ValueError(f"{n} devices not divisible by {nodes} nodes")
        local = n // nodes
    return _make_mesh((nodes, local), ("node", "local"))


def train_and_eval(
    arch: str,
    optimizer: str,
    steps: int,
    *,
    transport: str = "fused_allgather",
    schedule: str | None = None,
    bucket_bytes: int | None = None,
    intra_axis: str | None = None,
    fuse_leaves: bool | None = None,
    backend: str | None = None,
    nodes: int | None = None,
    lr: float = 0.1,
    momentum: float = 0.9,
    density: float = 0.01,
    local_clip: float | None = None,
    warmup_steps_per_stage: int = 0,
    dense_warmup: bool = False,
    seed: int = 0,
    batch: int = 8,
    seq_len: int = 64,
    eval_batches: int = 4,
    log_every: int = 0,
    use_mesh: bool = True,
) -> dict[str, Any]:
    """One real training run on the simulated cluster + held-out loss.

    ``nodes=N`` runs on the 2-axis ``("node","local")`` mesh (N nodes x
    devices/N locals) instead of the flat ``("data",)`` mesh — the
    hierarchical transport's home. ``bucket_bytes`` / ``intra_axis`` /
    ``fuse_leaves`` / ``backend`` / ``schedule`` parameterize the
    transport / flat-arena / selection-kernel / §5.6-overlap-scheduler
    knobs (None = the TrainConfig defaults).

    Returns ``{"held_loss", "losses", "num_devices", "steps", "digest"}``;
    ``losses`` is the per-step training-loss trace (loss is pmean'd over
    workers inside the step, so it is the global-batch loss) and
    ``digest`` is a sha256 over the final params + optimizer-state bytes
    — equal digests across subprocess runs mean BITWISE-identical
    training (what the arena parity tests assert).
    """
    import dataclasses
    import hashlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import TrainConfig, get_config
    from repro.data import bigram_batches
    from repro.train.trainer import Trainer

    cfg = get_config(arch, smoke=True)
    tc = TrainConfig(lr=lr, momentum=momentum, optimizer=optimizer,
                     transport=transport, density=density,
                     local_clip=local_clip,
                     warmup_steps_per_stage=warmup_steps_per_stage,
                     dense_warmup=dense_warmup, seed=seed)
    overrides = {k: v for k, v in
                 (("bucket_bytes", bucket_bytes), ("intra_axis", intra_axis),
                  ("fuse_leaves", fuse_leaves), ("backend", backend),
                  ("schedule", schedule))
                 if v is not None}
    if overrides:
        tc = dataclasses.replace(tc, **overrides)
    if not use_mesh:
        mesh = None
    elif nodes is not None:
        mesh = make_node_mesh(nodes)
    else:
        mesh = make_data_mesh()
    tr = Trainer(cfg, tc, mesh=mesh)
    state = tr.init_state()

    losses: list[float] = []
    state = tr.run(state, bigram_batches(cfg.vocab_size, batch, seq_len,
                                         seed=seed),
                   steps, log_every=log_every,
                   on_metrics=lambda step, dens, loss: losses.append(loss))

    # held-out loss: fresh batches from the same chain, past the train span
    src = bigram_batches(cfg.vocab_size, batch, seq_len, seed=seed)
    for _ in range(steps):
        next(src)
    held = 0.0
    for _ in range(eval_batches):
        b = {k: jnp.asarray(v) for k, v in next(src).items()}
        held += float(tr.model.loss(state.params, b))

    digest = hashlib.sha256()
    for leaf in (jax.tree.leaves(state.params) + jax.tree.leaves(state.rgc)):
        digest.update(np.asarray(leaf).tobytes())
    return {
        "held_loss": held / eval_batches,
        "losses": losses,
        "num_devices": len(jax.devices()) if use_mesh else 1,
        "steps": state.step,
        "digest": digest.hexdigest(),
    }


def run_cluster(spec: dict[str, Any], *, devices: int = 8,
                timeout: int = 1200) -> dict[str, Any]:
    """Run ``train_and_eval(**spec)`` on ``devices`` forced host devices in
    a subprocess; returns its result dict."""
    proc = subprocess.run(
        [sys.executable, CLUSTER_PROG,
         json.dumps({"devices": devices, "run": spec})],
        capture_output=True, text=True, env=subprocess_env(),
        timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"cluster run failed ({spec.get('arch')}/"
            f"{spec.get('optimizer')}):\nSTDOUT:\n{proc.stdout[-3000:]}\n"
            f"STDERR:\n{proc.stderr[-3000:]}")
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in cluster output:\n{proc.stdout}")


def convergence_pair(
    arch: str,
    steps: int = 200,
    *,
    devices: int = 8,
    sparse_optimizer: str = "momentum+clip(threshold_bsearch)",
    density: float = 0.01,
    warmup_steps_per_stage: int = 25,
    dense_warmup: bool = False,
    lr: float = 0.1,
    momentum: float = 0.9,
    local_clip: float = 1.0,
    seed: int = 0,
    timeout: int = 1200,
) -> dict[str, Any]:
    """Sparse-with-corrections vs dense ``psum`` on the same mesh/budget.

    The tier-2 convergence-parity criterion: the corrected sparse run's
    held-out loss lands within tolerance of the dense baseline's. The
    dense baseline gets the SAME local clipping (DGC clips both sides of
    its comparisons; an unclipped baseline would measure the clip, not
    the sparsification).
    """
    common = dict(arch=arch, steps=steps, lr=lr, momentum=momentum,
                  local_clip=local_clip, seed=seed)
    dense = run_cluster(dict(common, optimizer="dense",
                             transport="dense_psum"),
                        devices=devices, timeout=timeout)
    sparse = run_cluster(dict(common, optimizer=sparse_optimizer,
                              density=density, local_clip=local_clip,
                              warmup_steps_per_stage=warmup_steps_per_stage,
                              dense_warmup=dense_warmup),
                         devices=devices, timeout=timeout)
    return {"dense": dense, "sparse": sparse,
            "dense_loss": dense["held_loss"],
            "sparse_loss": sparse["held_loss"]}
