"""Threshold-reuse lifecycle (§5.2.2) + the fused multi-arena select.

Covers the tentpole's contract surface:

* per-leaf interval wrap: refresh every ``interval`` steps, filter at the
  cached threshold in between, ``LeafState.interval``/``threshold``
  bookkeeping — on BOTH backends (the pallas path historically always
  re-searched and never bumped the interval);
* segmented per-arena refresh STAGGERING: each slot refreshes on its own
  counter, so staggered states freeze/search independently within one
  fused launch;
* warm-vs-cold equivalence on the exact path, end to end;
* ``multi_select`` across several arenas at once is bitwise the
  per-arena calls (the one-launch-per-step fusion changes dispatch
  count, never results).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import registry
from repro.core.arena import ARENA_BLOCK, single_slot_geometry
from repro.core.residual import init_leaf
from repro.kernels import segmented as kseg
from repro.kernels.ops import _to2d


def _vec(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n), jnp.float32)


def _comp(name="threshold_bsearch", **kw):
    return registry.make(registry.COMPRESSOR, name, **kw)


def _state(n):
    return init_leaf(jnp.zeros((n,), jnp.float32), momentum=False)


class TestIntervalLifecycle:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_interval_wraps_and_reuses(self, backend):
        """interval=3: steps 0,3 refresh (new threshold), steps 1,2,4
        reuse the cached one verbatim; the counter increments every
        step on both backends."""
        comp = _comp(backend=backend, bsearch_interval=3)
        n, k = 6000, 16
        st = _state(n)
        thrs = []
        for step in range(5):
            x = _vec(n, seed=100 + step) * (1.0 + 0.3 * step)
            sel, st = comp.compress(x, k, st)
            assert int(st.interval) == step + 1
            thrs.append(float(st.threshold))
        # reuse steps keep the cached threshold bitwise
        assert thrs[1] == thrs[0] and thrs[2] == thrs[0]
        assert thrs[4] == thrs[3]
        # refresh steps actually re-search (the scaled data moved the
        # band, so an unchanged threshold would mean a dead re-search)
        assert thrs[3] != thrs[2]

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_reuse_step_filters_at_cached(self, backend):
        from repro.core import selection as sel_lib
        comp = _comp(backend=backend, bsearch_interval=4)
        n, k = 5000, 8
        st = _state(n)
        x0 = _vec(n, seed=1)
        _, st = comp.compress(x0, k, st)          # step 0: refresh
        x1 = _vec(n, seed=2)
        sel, st2 = comp.compress(x1, k, st)       # step 1: reuse
        ref = sel_lib.threshold_filter(x1, st.threshold, capacity=2 * k)
        np.testing.assert_array_equal(np.asarray(sel.indices),
                                      np.asarray(ref.indices))
        assert int(sel.count) == int(ref.count)
        assert float(st2.threshold) == float(st.threshold)

    def test_sampled_interval_lifecycle(self):
        comp = _comp("sampled_bsearch", bsearch_interval=2,
                     sampled_tolerance=0.5)
        n, k = 9000, 32
        st = _state(n)
        thrs = []
        for step in range(4):
            x = _vec(n, seed=200 + step) * (1.0 + 0.5 * step)
            _, st = comp.compress(x, k, st)
            thrs.append(float(st.threshold))
        assert thrs[1] == thrs[0]                  # reuse
        assert thrs[2] != thrs[1]                  # refresh re-searched
        assert thrs[3] == thrs[2]


def _arena(sizes, ks, seed):
    """A little hand-built arena: x2d stack + geometry for given slots."""
    from repro.core.arena import stack_geometries
    geoms, x_rows = [], []
    for s, (n, k) in enumerate(zip(sizes, ks)):
        geoms.append(single_slot_geometry(n, k))
        x2d, _ = _to2d(_vec(n, seed=seed + s), ARENA_BLOCK)
        x_rows.append(x2d)
    return jnp.concatenate(x_rows, axis=0), stack_geometries(geoms)


class TestSegmentedStaggering:
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_per_slot_refresh_staggering(self, use_pallas):
        """Slots with different interval phases refresh independently
        inside ONE fused launch: frozen slots keep their cached
        thresholds bitwise while refreshing slots re-search."""
        x2d, geom = _arena([3000, 5000, 2000], [8, 16, 4], seed=31)
        cached = jnp.asarray([0.9, 1.1, 0.7], jnp.float32)
        refresh = jnp.asarray([True, False, True])
        sel, thr = kseg.threshold_bsearch_segments(
            x2d, geom, use_pallas=use_pallas, interpret=True,
            refresh=refresh, cached=cached)
        thr = np.asarray(thr)
        assert thr[1] == np.float32(1.1)           # frozen slot untouched
        assert thr[0] != np.float32(0.9)
        assert thr[2] != np.float32(0.7)
        # frozen slot's selection is the filter at its cached threshold
        from repro.core import selection as sel_lib
        flat1 = _vec(5000, seed=32)
        ref = sel_lib.threshold_filter(flat1, jnp.float32(1.1),
                                       capacity=32)
        np.testing.assert_array_equal(np.asarray(sel[1].indices),
                                      np.asarray(ref.indices))

    def test_warm_vs_cold_segmented_same_band(self):
        """Warm seeding never changes the band contract, only the
        iterate path; both land k <= nnz <= 2k (or exhausted)."""
        x2d, geom = _arena([4000, 6000], [16, 32], seed=41)
        cold, thr_c = kseg.threshold_bsearch_segments(
            x2d, geom, use_pallas=False)
        warm, thr_w = kseg.threshold_bsearch_segments(
            x2d, geom, use_pallas=False,
            refresh=jnp.asarray([True, True]),
            cached=jnp.asarray(thr_c), warm=True)
        # the previous converged thresholds are in band -> accepted
        np.testing.assert_array_equal(np.asarray(thr_w),
                                      np.asarray(thr_c))
        for a, b in zip(warm, cold):
            np.testing.assert_array_equal(np.asarray(a.indices),
                                          np.asarray(b.indices))


class TestMultiSelectFusion:
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_multi_part_bitwise_per_part(self, use_pallas):
        """One multi_select over several arenas == the per-arena calls,
        bitwise — stacking changes dispatch count, never results."""
        xa, ga = _arena([3000, 1500], [8, 4], seed=51)
        xb, gb = _arena([7000], [32], seed=61)
        spec_t = kseg.SegmentSpec(alg="trimmed", eps=0.2)
        spec_b = kseg.SegmentSpec(alg="bsearch", eps=1e-3)
        fused = kseg.multi_select(
            [(xa, ga, spec_t, None), (xb, gb, spec_b, None)],
            use_pallas=use_pallas, interpret=True)
        solo_a = kseg.multi_select([(xa, ga, spec_t, None)],
                                   use_pallas=use_pallas, interpret=True)
        solo_b = kseg.multi_select([(xb, gb, spec_b, None)],
                                   use_pallas=use_pallas, interpret=True)
        for (sels_f, thr_f), (sels_s, thr_s) in zip(fused,
                                                    solo_a + solo_b):
            np.testing.assert_array_equal(np.asarray(thr_f),
                                          np.asarray(thr_s))
            for sf, ss in zip(sels_f, sels_s):
                np.testing.assert_array_equal(np.asarray(sf.indices),
                                              np.asarray(ss.indices))
                np.testing.assert_array_equal(np.asarray(sf.values),
                                              np.asarray(ss.values))

    def test_mixed_alg_parts_match_wrappers(self):
        """Trimmed and bsearch arenas share the unified loop; each still
        walks its own per-leaf iterate sequence."""
        from repro.core import selection as sel_lib
        xa, ga = _arena([2500], [8], seed=71)
        spec_t = kseg.SegmentSpec(alg="trimmed", eps=0.2)
        ((sels, _),) = kseg.multi_select([(xa, ga, spec_t, None)],
                                         use_pallas=False)
        per_leaf = sel_lib.trimmed_topk(_vec(2500, seed=71), 8, 0.2)
        np.testing.assert_array_equal(np.asarray(sels[0].indices),
                                      np.asarray(per_leaf.indices))


class TestSampledSegmented:
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_stride_one_bitwise_exact(self, use_pallas):
        x2d, geom = _arena([4000, 2000], [16, 8], seed=81)
        exact, thr_e = kseg.threshold_bsearch_segments(
            x2d, geom, use_pallas=use_pallas, interpret=True)
        samp, thr_s = kseg.threshold_bsearch_segments(
            x2d, geom, use_pallas=use_pallas, interpret=True,
            strides=(1, 1), capacities=(32, 16))
        np.testing.assert_array_equal(np.asarray(thr_s),
                                      np.asarray(thr_e))
        for a, b in zip(samp, exact):
            np.testing.assert_array_equal(np.asarray(a.indices),
                                          np.asarray(b.indices))

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_segmented_sampled_matches_per_leaf(self, use_pallas):
        """Sampled segmented vs sampled per-leaf: the jnp twin counts the
        identical slot-local [::stride] grid and matches BITWISE; the
        pallas kernel reduces block-by-block, so its subsample mean (and
        hence threshold) may drift by an ulp — there we pin closeness
        plus filter self-consistency at the landed threshold."""
        from repro.core import selection as sel_lib
        sizes, ks, stride = [6000, 3000], [32, 16], 4
        caps = [96, 48]
        x2d, geom = _arena(sizes, ks, seed=91)
        sels, thr = kseg.threshold_bsearch_segments(
            x2d, geom, use_pallas=use_pallas, interpret=True,
            strides=(stride, stride), capacities=tuple(caps))
        for s, (n, k, cap) in enumerate(zip(sizes, ks, caps)):
            flat = _vec(n, seed=91 + s)
            ref, thr_ref = sel_lib.sampled_threshold_search(
                flat, k, stride=stride, capacity=cap)
            if use_pallas:
                np.testing.assert_allclose(float(thr[s]), float(thr_ref),
                                           rtol=1e-5)
                flt = sel_lib.threshold_filter(flat, thr[s], capacity=cap)
                np.testing.assert_array_equal(np.asarray(sels[s].indices),
                                              np.asarray(flt.indices))
            else:
                assert float(thr[s]) == float(thr_ref)
                np.testing.assert_array_equal(np.asarray(sels[s].indices),
                                              np.asarray(ref.indices))


class TestWarmVsColdEndToEnd:
    def test_exact_path_warm_equals_cold(self):
        """On static-band data the warm bracket accepts or converges to
        the same in-band threshold: end-to-end params match cold."""
        from repro.core import build_gradient_sync
        rng = np.random.default_rng(5)
        params = {"a": jnp.zeros((100, 64), jnp.float32),
                  "b": jnp.zeros((50, 40), jnp.float32)}
        grads = {k: jnp.asarray(rng.normal(size=v.shape), jnp.float32)
                 for k, v in params.items()}

        def run(warm):
            sync = build_gradient_sync("threshold_bsearch", density=0.01,
                                       warm_start=warm)
            step = jax.jit(lambda g, s, p: sync.update(
                g, s, p, jnp.float32(0.1)))
            st = sync.init(params)
            p = params
            for _ in range(4):
                p, st = step(grads, st, p)
            return p

        a, b = run(True), run(False)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
