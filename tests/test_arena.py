"""Flat residual arena tests: layout invariants (hypothesis round-trips),
bitwise parity of the fused pipeline vs the per-leaf pipeline (eager, jit,
both selection backends, corrections, bf16 residuals), dispatch-count
reduction, the per-step plan cache, fallback rules, and the 8-device
subprocess / real-Trainer parity runs."""
import math
import os

import numpy as np
import pytest

ARENA_PROG = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "_arena_prog.py")

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

SIZES = {"a": 33_001, "big": 300_000, "c": 500, "single": 1}


def _tree(seed=0, sizes=SIZES):
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    params = {k: jnp.asarray(rng.standard_normal(n), jnp.float32)
              for k, n in sizes.items()}
    grads = jax.tree.map(lambda p: p * 0.01, params)
    return params, grads


def _run(params, grads, fuse, steps=3, jit=True, timer=None, **kw):
    import jax
    import jax.numpy as jnp

    from repro.core import build_gradient_sync
    sync = build_gradient_sync(
        kw.pop("spec", "rgc"), transport="fused_allgather", sync_axes=(),
        density=0.01, dense_threshold_bytes=2048, fuse_leaves=fuse,
        timer=timer, **kw)
    st = sync.init(params)
    step = (lambda p, st: sync.update(grads, st, p, jnp.float32(0.1)))
    if jit:
        step = jax.jit(step)
    p = params
    for _ in range(steps):
        p, st = step(p, st)
    return p, st


def _assert_bitwise(a, b):
    import jax
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype
        assert np.array_equal(x, y, equal_nan=True), \
            f"max|d|={np.max(np.abs(x.astype(np.float64) - y))}"


# ---------------------------------------------------------------------------
# layout invariants
# ---------------------------------------------------------------------------

def _build(sizes, dtype="float32"):
    from repro.core import arena
    return arena.build_group(
        0, "trimmed_topk", dtype,
        [(i, f"leaf{i}", n, max(1, math.ceil(0.01 * n)),
          max(1, math.ceil(0.01 * n)), 1 + 2 * max(1, math.ceil(0.01 * n)))
         for i, n in enumerate(sizes)])


class TestLayout:
    def test_alignment_and_no_overlap(self):
        from repro.core.arena import ARENA_BLOCK
        g = _build([1, 1023, 1024, 1025, 50_000])
        spans = []
        for s in g.slots:
            assert s.offset % ARENA_BLOCK == 0
            assert s.padded % ARENA_BLOCK == 0
            assert s.padded >= s.size
            assert s.padded - s.size < ARENA_BLOCK
            spans.append((s.offset, s.offset + s.padded))
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0, "slots overlap"
        assert g.total == spans[-1][1]

    def test_geometry_maps(self):
        g = _build([1023, 2049, 7])
        geom = g.geometry
        assert geom.nblocks == g.nblocks
        for s_ord, slot in enumerate(g.slots):
            r0, r1 = slot.rows
            assert list(geom.block_seg[r0:r1]) == [s_ord] * slot.nblocks
            assert list(geom.block_base[r0:r1]) == \
                [i * 1024 for i in range(slot.nblocks)]
            assert all(geom.block_size[r0:r1] == slot.size)

    def test_message_layout(self):
        from repro.core.sync import message_len
        g = _build([1000, 2000])
        off = 0
        for s in g.slots:
            assert s.msg_offset == off
            assert s.msg_len == message_len(s.capacity, False)
            off += s.msg_len
        assert g.msg_total == off

    @staticmethod
    def _roundtrip(sizes, seed):
        import jax.numpy as jnp

        from repro.core import arena
        g = _build(sizes)
        rng = np.random.default_rng(seed)
        arrs = [jnp.asarray(rng.standard_normal(n), jnp.float32)
                for n in sizes]
        a2d = arena.gather(g, arrs)
        assert a2d.shape == (g.nblocks, arena.ARENA_BLOCK)
        back = arena.scatter(g, a2d)
        for slot in g.slots:
            np.testing.assert_array_equal(np.asarray(back[slot.leaf]),
                                          np.asarray(arrs[slot.leaf]))
        # inter-slot padding is zero-filled
        flat = np.asarray(a2d).reshape(-1)
        mask = np.ones(g.total, bool)
        for slot in g.slots:
            mask[slot.offset:slot.offset + slot.size] = False
        assert np.all(flat[mask] == 0.0)

    @pytest.mark.parametrize("sizes,seed", [
        ([1], 0), ([1024], 1), ([1023, 1025], 2),
        ([1, 1, 1], 3), ([5000, 7, 2048, 999], 4),
    ])
    def test_gather_scatter_roundtrip_grid(self, sizes, seed):
        """Deterministic twin of the hypothesis round-trip (runs even
        without hypothesis installed)."""
        self._roundtrip(sizes, seed)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=25, deadline=None)
        @given(st.lists(st.integers(min_value=1, max_value=5000),
                        min_size=1, max_size=8),
               st.integers(min_value=0, max_value=2**31 - 1))
        def test_gather_scatter_roundtrip(self, sizes, seed):
            self._roundtrip(sizes, seed)

    def test_group_partitioning_by_dtype_and_compressor(self):
        """One arena never mixes dtypes or selection algorithms."""
        import jax.numpy as jnp

        from repro.core import build_gradient_sync
        params = {"f32_big": jnp.zeros(300_000, jnp.float32),
                  "bf16_big": jnp.zeros(300_000, jnp.bfloat16),
                  "f32_mid": jnp.zeros(40_000, jnp.float32),
                  "bf16_mid": jnp.zeros(40_000, jnp.bfloat16)}
        sync = build_gradient_sync("rgc", density=0.01,
                                   dense_threshold_bytes=2048)
        grads = params
        import jax
        leaves, treedef = jax.tree.flatten(grads)
        plan = sync._plan(grads, treedef, leaves, 0.01, False)
        for group in plan.groups:
            dts = {str(leaves[s.leaf].dtype) for s in group.slots}
            assert dts == {group.dtype}
        keys = [(g.compressor, g.dtype) for g in plan.groups]
        assert len(keys) == len(set(keys))
        # 40 KB*4 = 160KB f32 -> trimmed; 80 KB bf16 -> ... real itemsize
        # dispatch means the same element count lands in different groups
        assert len(plan.groups) >= 2


# ---------------------------------------------------------------------------
# bitwise parity, single process
# ---------------------------------------------------------------------------

class TestBitwiseParity:
    @pytest.mark.parametrize("jit", [False, True])
    def test_rgc_mixed_tree(self, jit):
        params, grads = _tree()
        _assert_bitwise(_run(params, grads, True, jit=jit),
                        _run(params, grads, False, jit=jit))

    def test_pallas_backend(self):
        params, grads = _tree(sizes={"a": 33_001, "big": 200_000, "c": 500})
        _assert_bitwise(_run(params, grads, True, backend="pallas"),
                        _run(params, grads, False, backend="pallas"))

    def test_corrections_spec(self):
        params, grads = _tree(1)
        kw = dict(spec="momentum+clip(threshold_bsearch)", local_clip=1.0)
        _assert_bitwise(_run(params, grads, True, **kw),
                        _run(params, grads, False, **kw))

    def test_weight_decay_and_nesterov(self):
        params, grads = _tree(2)
        kw = dict(weight_decay=0.01, nesterov=True)
        _assert_bitwise(_run(params, grads, True, **kw),
                        _run(params, grads, False, **kw))

    def test_bf16_residual(self):
        import jax.numpy as jnp
        params, grads = _tree(3)
        kw = dict(residual_dtype=jnp.bfloat16)
        _assert_bitwise(_run(params, grads, True, **kw),
                        _run(params, grads, False, **kw))

    def test_single_leaf_and_momentumless(self):
        params, grads = _tree(4, sizes={"w": 200_000})
        kw = dict(momentum=0.0)
        _assert_bitwise(_run(params, grads, True, **kw),
                        _run(params, grads, False, **kw))

    def test_fuse_accumulate_exact_when_momentumless(self):
        """The single-pass fused accumulate kernel is bitwise when there
        is no momentum/weight-decay product to contract."""
        params, grads = _tree(5)
        kw = dict(momentum=0.0)
        _assert_bitwise(_run(params, grads, True, fuse_accumulate=True, **kw),
                        _run(params, grads, False, **kw))

    def test_fuse_accumulate_close_with_momentum(self):
        """With momentum the fused kernel may differ by ulps (documented
        FMA caveat) but must track the per-leaf path closely."""
        import jax
        params, grads = _tree(6)
        a = _run(params, grads, True, fuse_accumulate=True)
        b = _run(params, grads, False)
        for x, y in zip(jax.tree.leaves(a[0]), jax.tree.leaves(b[0])):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# dispatch accounting + plan cache + fallbacks
# ---------------------------------------------------------------------------

def _counts(fuse, **kw):
    from repro.core import WallClockTimer
    params, grads = _tree()
    timer = WallClockTimer()
    _run(params, grads, fuse, steps=1, jit=False, timer=timer, **kw)
    return timer.summary()["counts"]


class TestDispatchCounts:
    def test_arena_reduces_select_mask_pack_to_arenas(self):
        per_leaf = _counts(False)
        fused = _counts(True)
        # 3 sparse leaves in SIZES ("a", "big" trimmed/bsearch; "c"/"single"
        # are dense at the 2048-byte threshold? c=2000B dense, single dense)
        for stage in ("select", "mask", "pack"):
            key = f"dispatch_{stage}"
            assert fused[key] < per_leaf[key]
        assert fused["messages"] < per_leaf["messages"]
        # accumulate stays per-leaf by default (bitwise graph)
        assert fused["dispatch_accumulate"] == per_leaf["dispatch_accumulate"]

    def test_fuse_accumulate_reduces_accumulate_dispatches(self):
        per_leaf = _counts(False)
        fused = _counts(True, fuse_accumulate=True)
        assert fused["dispatch_accumulate"] < per_leaf["dispatch_accumulate"]

    def test_quantized_falls_back_per_leaf(self):
        fused = _counts(True, spec="quantized(trimmed_topk)")
        per_leaf = _counts(False, spec="quantized(trimmed_topk)")
        assert fused == per_leaf   # no segmented impl -> identical pipeline


class TestPlanCache:
    def test_plan_reused_across_steps(self):
        import jax.numpy as jnp

        from repro.core import build_gradient_sync
        params, grads = _tree()
        sync = build_gradient_sync("rgc", density=0.01,
                                   dense_threshold_bytes=2048)
        st = sync.init(params)
        p, st = sync.update(grads, st, params, jnp.float32(0.1))
        assert len(sync._plans) == 1
        plan = next(iter(sync._plans.values()))
        sync.update(grads, st, p, jnp.float32(0.1))
        assert len(sync._plans) == 1
        assert next(iter(sync._plans.values())) is plan

    def test_density_keys_new_plan(self):
        import jax.numpy as jnp

        from repro.core import build_gradient_sync
        params, grads = _tree()
        sync = build_gradient_sync("rgc", density=0.01,
                                   dense_threshold_bytes=2048)
        st = sync.init(params)
        sync.update(grads, st, params, jnp.float32(0.1))
        sync.update(grads, st, params, jnp.float32(0.1), density=0.05)
        sync.update(grads, st, params, jnp.float32(0.1), density=1.0)
        assert len(sync._plans) == 3
        dense_plan = sync._plans[next(
            k for k in sync._plans if k[-1])]     # all_dense key
        assert not dense_plan.groups and not dense_plan.sparse

    def test_dispatch_sees_raw_gradient_dtype(self):
        """The plan is built BEFORE corrections run, so §5.5 dispatch
        sees the parameter's real storage dtype even with local_clip
        enabled (whose f32 upcast used to leak into the byte-size
        dispatch): a 48K-element bf16 leaf is 96 KB -> dense, not the
        192 KB -> trimmed its f32-upcast view would suggest."""
        import jax
        import jax.numpy as jnp

        from repro.core import build_gradient_sync
        grads = {"w": jnp.zeros(48 * 1024, jnp.bfloat16)}
        sync = build_gradient_sync("rgc", local_clip=1.0)
        leaves, treedef = jax.tree.flatten(grads)
        plan = sync._plan(grads, treedef, leaves, 0.001, False)
        assert plan.dense == (0,)
        assert not plan.groups and not plan.sparse

    def test_custom_correction_disables_fusion(self):
        import jax.numpy as jnp

        from repro.core import build_gradient_sync
        from repro.core.correction import CorrectionBase

        class Weird(CorrectionBase):
            name = "weird"

            def accumulate(self, grad, param, state, *, weight_decay):
                return state._replace(residual=grad.astype(jnp.float32))

        params, grads = _tree()
        sync = build_gradient_sync("rgc", density=0.01,
                                   dense_threshold_bytes=2048)
        sync.corrections = (Weird(),) + sync.corrections
        sync._arena_ok = all(c.arena_safe() for c in sync.corrections)
        assert not sync._arena_ok
        import jax
        leaves, treedef = jax.tree.flatten(grads)
        plan = sync._plan(grads, treedef, leaves, 0.01, False)
        assert not plan.groups     # everything stays per-leaf
        assert plan.sparse


# ---------------------------------------------------------------------------
# numerics pins (the contraction fences the parity above rests on)
# ---------------------------------------------------------------------------

class TestPinnedNumerics:
    def test_pinned_product_value(self):
        import jax
        import jax.numpy as jnp

        from repro.core.residual import pinned_product
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal(4096), jnp.float32)
        b = jnp.asarray(rng.standard_normal(4096), jnp.float32)
        want = np.asarray(a) * np.asarray(b)
        np.testing.assert_array_equal(np.asarray(pinned_product(a, b)), want)
        np.testing.assert_array_equal(
            np.asarray(jax.jit(pinned_product)(a, b)), want)

    def test_pinned_sum_is_context_independent(self):
        import jax
        import jax.numpy as jnp

        from repro.core.selection import pinned_sum
        rng = np.random.default_rng(1)
        x = jnp.asarray(np.abs(rng.standard_normal(33_001)), jnp.float32)
        plain = float(pinned_sum(x))
        jitted = float(jax.jit(pinned_sum)(x))
        # and embedded in a bigger graph
        bigger = float(jax.jit(
            lambda x: pinned_sum(x) + 0 * jnp.max(x))(x))
        assert plain == jitted == bigger

    def test_pinned_sum_empty_pad(self):
        import jax.numpy as jnp

        from repro.core.selection import pinned_sum
        assert float(pinned_sum(jnp.asarray([3.5], jnp.float32))) == 3.5


# ---------------------------------------------------------------------------
# 8-device subprocess + real-Trainer parity
# ---------------------------------------------------------------------------

def test_arena_parity_8dev(run_prog):
    out = run_prog(ARENA_PROG)
    assert "FAIL" not in out


def test_trainer_bitwise_parity_on_cluster():
    """Real Trainer, 8-device simulated cluster, multi-step: the fused
    arenas must reproduce the per-leaf run BITWISE (params + optimizer
    state digests and the loss trace)."""
    from harness import run_cluster

    spec = dict(arch="paper-lstm", optimizer="rgc", steps=6, density=0.01)
    fused = run_cluster(dict(spec, fuse_leaves=True), devices=8)
    per_leaf = run_cluster(dict(spec, fuse_leaves=False), devices=8)
    assert fused["num_devices"] == 8
    assert fused["losses"] == per_leaf["losses"]
    assert fused["digest"] == per_leaf["digest"]
