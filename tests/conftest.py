"""Shared test fixtures: subprocess runner for multi-device programs,
smoke-model factories, and the tier-2 gate.

Tier structure:
  * tier-1 — everything collected by plain ``pytest -q`` (fast; the
    CI matrix runs it on legacy AND modern jax).
  * tier-2 — ``@pytest.mark.tier2`` convergence-harness tests (8-way
    simulated cluster, hundreds of real training steps). Skipped by
    default; enable with ``--run-tier2`` or ``RUN_TIER2=1``.

Multi-device tests need ``--xla_force_host_platform_device_count`` set
before jax initializes, so they run their programs in a subprocess via the
``run_prog`` fixture (the main pytest process keeps its single-device
view, per the project rule of never forcing device counts globally).
"""
import dataclasses
import os
import subprocess
import sys

import pytest

from harness.cluster import subprocess_env

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))


def pytest_addoption(parser):
    parser.addoption(
        "--run-tier2", action="store_true", default=False,
        help="run tier-2 convergence-harness tests (slow, 8-way simulated "
             "cluster)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tier2: slow simulated-cluster convergence tests (enable with "
        "--run-tier2 or RUN_TIER2=1)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-tier2") or os.environ.get("RUN_TIER2") == "1":
        return
    skip = pytest.mark.skip(
        reason="tier-2: enable with --run-tier2 or RUN_TIER2=1")
    for item in items:
        if "tier2" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def run_prog():
    """Run a standalone test program in a subprocess with src+tests on
    PYTHONPATH; asserts exit 0 and an ``OK`` line on stdout."""
    def _run(prog_path: str, *args: str, timeout: int = 900) -> str:
        proc = subprocess.run(
            [sys.executable, prog_path, *args],
            capture_output=True, text=True, env=subprocess_env(),
            timeout=timeout)
        if proc.returncode != 0:
            raise AssertionError(
                f"{os.path.basename(prog_path)} {' '.join(args)} failed:\n"
                f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}")
        assert "OK" in proc.stdout
        return proc.stdout
    return _run


@pytest.fixture
def smoke_config():
    """``smoke_config(arch, **overrides)`` — reduced ModelConfig."""
    from repro.configs import get_config

    def _cfg(arch: str, **overrides):
        cfg = get_config(arch, smoke=True)
        return dataclasses.replace(cfg, **overrides) if overrides else cfg
    return _cfg


@pytest.fixture
def smoke_model(smoke_config):
    """``smoke_model(arch, **overrides)`` — Model over the smoke config."""
    from repro.models.registry import get_model

    def _model(arch: str, **overrides):
        return get_model(smoke_config(arch, **overrides))
    return _model
