"""Transport-layer tests: bucket assignment, registration, single-worker
bitwise parity, the stage-timer hook, and the 8-device subprocess parity
program (bucketed / hierarchical vs fused, mixed-size and single-leaf
pytrees on the simulated cluster)."""
import os

import numpy as np
import pytest

TRANSPORT_PROG = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "_transport_prog.py")


# ---------------------------------------------------------------------------
# bucket-boundary assignment (pure python, pinned)
# ---------------------------------------------------------------------------

class TestAssignBuckets:
    def test_pinned_layout(self):
        from repro.core.transport import assign_buckets
        # greedy contiguous fill: a message joins the open bucket unless
        # it would overflow the budget
        assert assign_buckets([100, 100, 100], 250) == [[0, 1], [2]]
        assert assign_buckets([100, 200, 50, 50, 300, 10], 300) == \
            [[0, 1], [2, 3], [4], [5]]

    def test_exact_fit_is_kept(self):
        from repro.core.transport import assign_buckets
        # boundary pin: filling the budget EXACTLY does not open a new
        # bucket; one byte more does
        assert assign_buckets([150, 150], 300) == [[0, 1]]
        assert assign_buckets([150, 151], 300) == [[0], [1]]

    def test_oversized_message_gets_own_bucket(self):
        from repro.core.transport import assign_buckets
        assert assign_buckets([500], 300) == [[0]]
        # an over-budget bucket never grows further: the trailing message
        # opens a fresh bucket rather than riding the oversized one
        assert assign_buckets([10, 500, 10], 300) == [[0], [1], [2]]

    def test_empty_and_invalid(self):
        from repro.core.transport import assign_buckets
        assert assign_buckets([], 300) == []
        with pytest.raises(ValueError):
            assign_buckets([10], 0)

    def test_nothing_dropped(self):
        from repro.core.transport import assign_buckets
        rng = np.random.default_rng(0)
        sizes = [int(s) for s in rng.integers(1, 5000, size=200)]
        buckets = assign_buckets(sizes, 8192)
        flat = [i for b in buckets for i in b]
        assert flat == list(range(len(sizes)))   # order-preserving, total
        assert all(b for b in buckets)


# ---------------------------------------------------------------------------
# registration + construction
# ---------------------------------------------------------------------------

def test_transports_registered():
    from repro.core import registry
    names = registry.names(registry.TRANSPORT)
    assert "bucketed_allgather" in names
    assert "hierarchical" in names


def test_hierarchical_axis_resolution():
    from repro.core.transport import HierarchicalAllgather
    t = HierarchicalAllgather(("node", "local"))
    assert t.intra_axis == "local" and t.inter_axes == ("node",)
    t = HierarchicalAllgather(("pod", "data"), intra_axis="pod")
    assert t.intra_axis == "pod" and t.inter_axes == ("data",)
    # fewer than two sync axes: no hierarchy to exploit -> flat gather
    t = HierarchicalAllgather(("data",))
    assert t.intra_axis is None and t.inter_axes == ("data",)
    with pytest.raises(ValueError):
        HierarchicalAllgather(("node", "local"), intra_axis="bogus")


def test_builder_threads_transport_knobs():
    from repro.core import build_gradient_sync
    sync = build_gradient_sync("rgc", transport="bucketed_allgather",
                               bucket_bytes=12345)
    assert sync.transport.bucket_bytes == 12345
    sync = build_gradient_sync("rgc", transport="hierarchical",
                               sync_axes=("node", "local"))
    assert sync.transport.intra_axis == "local"


# ---------------------------------------------------------------------------
# single-worker bitwise parity (eager, sync_axes=()): every transport must
# agree with fused exactly when p=1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transport,kw", [
    ("bucketed_allgather", {"bucket_bytes": 30_000}),
    ("hierarchical", {}),
    ("per_leaf_allgather", {}),
])
def test_single_worker_parity(transport, kw):
    import jax
    import jax.numpy as jnp

    from repro.core import build_gradient_sync

    rng = np.random.default_rng(0)
    params = {"a": jnp.asarray(rng.standard_normal(50_000), jnp.float32),
              "b": jnp.asarray(rng.standard_normal(40_000), jnp.float32),
              "c": jnp.asarray(rng.standard_normal(500), jnp.float32)}
    grads = jax.tree.map(lambda p: p * 0.01, params)

    def run(name, **tkw):
        sync = build_gradient_sync("rgc", transport=name, sync_axes=(),
                                   density=0.01, dense_threshold_bytes=4096,
                                   **tkw)
        st = sync.init(params)
        return sync.update(grads, st, params, jnp.float32(0.1))

    ref_p, ref_s = run("fused_allgather")
    got_p, got_s = run(transport, **kw)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(got_p)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ref_s), jax.tree.leaves(got_s)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# stage-timer hook
# ---------------------------------------------------------------------------

def test_stage_set_pinned():
    """Fig 10's stage axis, with the paper's "mask" bar split into
    ``accumulate`` (Alg 4 l.8-19 residual/momentum accumulation) and
    ``mask`` (l.21-23 state clearing) — summing the two recovers the
    paper's bar. Benchmarks and docs key on these exact names."""
    from repro.core import STAGES
    assert STAGES == ("accumulate", "select", "mask", "pack", "transfer",
                      "unpack")


def test_wallclock_timer_records_stages():
    import jax
    import jax.numpy as jnp

    from repro.core import STAGES, WallClockTimer, build_gradient_sync

    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.standard_normal(60_000), jnp.float32),
              "b": jnp.asarray(rng.standard_normal(100), jnp.float32)}
    grads = jax.tree.map(lambda p: p * 0.01, params)

    timer = WallClockTimer()
    sync = build_gradient_sync("rgc", transport="bucketed_allgather",
                               sync_axes=(), density=0.01,
                               dense_threshold_bytes=4096, timer=timer)
    assert sync.transport.timer is timer     # one hook, shared
    st = sync.init(params)
    sync.update(grads, st, params, jnp.float32(0.1))

    summ = timer.summary()
    for stage in STAGES:
        assert stage in summ["stages"], f"missing stage {stage}"
        assert summ["stages"][stage]["calls"] >= 1
        assert summ["stages"][stage]["total_s"] >= 0.0
    assert summ["counts"]["buckets"] >= 1
    assert abs(sum(s["share"] for s in summ["stages"].values()) - 1.0) < 1e-9
    timer.reset()
    assert timer.summary()["total_s"] == 0


def test_null_timer_is_passthrough():
    from repro.core import NullTimer
    t = NullTimer()
    assert t.stage("select", lambda: 42) == 42
    t.count("buckets", 3)
    assert t.summary() == {}


# ---------------------------------------------------------------------------
# cost model: Eq 1 terms are the single source of the benchmark math
# ---------------------------------------------------------------------------

def test_eq1_terms_sum_to_t_sparse():
    from repro.core.cost_model import PIZ_DAINT, eq1_terms, t_sparse
    for p in (2, 32, 128):
        terms = eq1_terms(p, 10_000_000, 0.001, PIZ_DAINT, t_select=0.002)
        assert set(terms) == {"select", "latency", "bandwidth", "unpack"}
        assert sum(terms.values()) == pytest.approx(
            t_sparse(p, 10_000_000, 0.001, PIZ_DAINT, t_select=0.002))


def test_predicted_shares_normalized():
    from repro.core.cost_model import PIZ_DAINT, predicted_shares
    sh = predicted_shares(128, 27_000_000, 0.001, PIZ_DAINT)
    assert sh["select"] + sh["transfer"] + sh["unpack"] == pytest.approx(1.0)
    assert sh["total_s"] > 0
    # t_select now derives from the model size: a 5x bigger model must not
    # report the same absolute select time (the old hard-coded 0.003 did)
    sh_big = predicted_shares(128, 5 * 27_000_000, 0.001, PIZ_DAINT)
    assert sh_big["total_s"] > sh["total_s"]


# ---------------------------------------------------------------------------
# the 8-device parity program (subprocess; real multi-worker collectives)
# ---------------------------------------------------------------------------

def test_transport_parity_8dev(run_prog):
    out = run_prog(TRANSPORT_PROG)
    assert "FAIL" not in out


def test_hierarchical_end_to_end_on_node_mesh():
    """Real Trainer runs on the harness's 2-axis ("node","local") mesh:
    the hierarchical transport must reproduce the fused transport's loss
    trajectory EXACTLY (bitwise param parity implies bitwise losses)."""
    from harness import run_cluster

    spec = dict(arch="paper-lstm", optimizer="rgc", steps=6,
                nodes=2, density=0.01)
    hier = run_cluster(dict(spec, transport="hierarchical"), devices=8)
    fused = run_cluster(dict(spec, transport="fused_allgather"), devices=8)
    assert hier["num_devices"] == 8
    assert hier["losses"] == fused["losses"]
    assert hier["held_loss"] == fused["held_loss"]
