"""Multi-device transport parity program, run as a subprocess by
test_transport.py with 8 forced host devices (the XLA flag must be set
before jax init, so it cannot run inside the main pytest process).

Checks that ``bucketed_allgather`` and ``hierarchical`` produce BITWISE
identical synced params and residual state to ``fused_allgather`` when
every worker compresses a different local gradient:

 1. bucketed vs fused on the harness ("data",)=8 mesh, over a mixed-size
    pytree whose messages do NOT fill buckets evenly (non-bucket-multiple)
    and with a bucket budget small enough to force several buckets.
 2. hierarchical vs fused on a 2-axis ("node","local") = (2,4) mesh — the
    §5.4 intra-node dense psum + inter-node sparse allgather composition.
 3. both, on a single-leaf model (one big sparse leaf, nothing to fuse).
 4. row-order sanity: the hierarchical two-hop exchange reassembles the
    gathered message matrix in the same worker order as the flat joint
    all_gather (checked implicitly by 2/3 being bitwise, and explicitly
    on a tagged payload here).
"""
import sys

from harness.cluster import check, force_host_devices

force_host_devices(8)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import build_gradient_sync
from repro.core import sync as sync_lib
from repro.jaxcompat import shard_map as shard_map_compat
from repro.launch.mesh import _make_mesh

STEPS = 3
LR = 0.1

# Mixed-size tree: >=4 MiB -> threshold_bsearch, 128 KB..4 MiB -> trimmed
# top-k, < 128 KB -> dense psum fallback. Sizes are deliberately not round
# so messages never tile a bucket budget exactly.
TREE_SIZES = {"big": (1 << 20) + 17, "mid": 96 * 1024 + 3,
              "mid2": 33_001, "small": 1_000}
SINGLE_SIZES = {"w": (1 << 20) + 17}


def make_mesh(axes):
    shapes = {("data",): (8,), ("node", "local"): (2, 4)}
    return _make_mesh(shapes[axes], axes)


def run_steps(transport, axes, sizes, **transport_kw):
    """STEPS sync steps on the mesh; every worker sees its own gradient
    stream. Returns (params, state) trees as host arrays."""
    mesh = make_mesh(axes)
    rng = np.random.default_rng(0)
    params = {k: jnp.asarray(rng.standard_normal(n), jnp.float32)
              for k, n in sizes.items()}
    # [workers, STEPS, n] per leaf, sharded over the batch axes on dim 0
    grads = {k: jnp.asarray(rng.standard_normal((8, STEPS, n)) * 0.01,
                            jnp.float32)
             for k, n in sizes.items()}

    sync = build_gradient_sync(
        "rgc", transport=transport, sync_axes=axes, density=0.01,
        momentum=0.9, **transport_kw)
    state0 = sync.init(params)

    def worker(gs, p, st):
        for t in range(STEPS):
            g_t = {k: g[0, t] for k, g in gs.items()}
            p, st = sync.update(g_t, st, p, jnp.float32(LR))
        return p, st

    f = jax.jit(shard_map_compat(
        worker, mesh=mesh,
        in_specs=({k: P(axes) for k in sizes}, P(),
                  jax.tree.map(lambda _: P(), state0)),
        out_specs=(P(), jax.tree.map(lambda _: P(), state0)),
        check_vma=False))
    p2, st2 = f(grads, params, state0)
    return (jax.tree.map(np.asarray, p2), jax.tree.map(np.asarray, st2))


def check_bitwise(name, got, want):
    leaves_g = jax.tree.leaves(got)
    leaves_w = jax.tree.leaves(want)
    same = all(a.dtype == b.dtype and np.array_equal(a, b, equal_nan=True)
               for a, b in zip(leaves_g, leaves_w))
    if not same:
        for a, b in zip(leaves_g, leaves_w):
            if not np.array_equal(a, b, equal_nan=True):
                print(f"  mismatch: max|d|="
                      f"{np.max(np.abs(a.astype(np.float64) - b)):.3e}")
    check(name, same)


def test_row_order():
    """Hierarchical gather must order rows exactly as the joint gather."""
    mesh = make_mesh(("node", "local"))

    def worker(x):
        flat = sync_lib.sparse_allgather(x[0], ("node", "local"))
        hier = sync_lib.hierarchical_allgather(x[0], ("node",), "local")
        return (flat == hier).all(), flat[:, 0]

    f = jax.jit(shard_map_compat(
        worker, mesh=mesh, in_specs=(P(("node", "local")),),
        out_specs=(P(), P()), check_vma=False))
    # tag each worker's message with its global rank
    tags = jnp.arange(8, dtype=jnp.float32).reshape(8, 1) * jnp.ones((8, 4))
    same, order = f(tags)
    check("hierarchical row order == joint all_gather order", bool(same))
    check("rows are node-major rank order",
          np.array_equal(np.asarray(order), np.arange(8, dtype=np.float32)))


def test_bucketed_parity():
    ref_p, ref_s = run_steps("fused_allgather", ("data",), TREE_SIZES)
    # ~40 KB budget: the big leaf's ~168 KB message overflows it alone
    # (singleton bucket) and the two mid messages split across buckets
    got_p, got_s = run_steps("bucketed_allgather", ("data",), TREE_SIZES,
                             bucket_bytes=40_000)
    check_bitwise("bucketed == fused params (mixed tree, 8 workers)",
                  got_p, ref_p)
    check_bitwise("bucketed == fused state (mixed tree, 8 workers)",
                  got_s, ref_s)


def test_hierarchical_parity():
    axes = ("node", "local")
    ref_p, ref_s = run_steps("fused_allgather", axes, TREE_SIZES)
    got_p, got_s = run_steps("hierarchical", axes, TREE_SIZES)
    check_bitwise("hierarchical == fused params (2x4 node mesh)",
                  got_p, ref_p)
    check_bitwise("hierarchical == fused state (2x4 node mesh)",
                  got_s, ref_s)
    # non-default intra hop: intra-node psum over the FIRST sync axis;
    # the gathered rows must be transposed back to sync_axes-major order,
    # so parity still holds bitwise
    got_p, got_s = run_steps("hierarchical", axes, TREE_SIZES,
                             intra_axis="node")
    check_bitwise("hierarchical(intra=node) == fused params",
                  got_p, ref_p)
    check_bitwise("hierarchical(intra=node) == fused state",
                  got_s, ref_s)


def test_single_leaf():
    ref_p, ref_s = run_steps("fused_allgather", ("data",), SINGLE_SIZES)
    got_p, _ = run_steps("bucketed_allgather", ("data",), SINGLE_SIZES,
                         bucket_bytes=40_000)
    check_bitwise("bucketed == fused params (single-leaf model)",
                  got_p, ref_p)
    ref2_p, _ = run_steps("fused_allgather", ("node", "local"), SINGLE_SIZES)
    got2_p, _ = run_steps("hierarchical", ("node", "local"), SINGLE_SIZES)
    check_bitwise("hierarchical == fused params (single-leaf model)",
                  got2_p, ref2_p)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    fns = {"order": test_row_order,
           "bucketed": test_bucketed_parity,
           "hierarchical": test_hierarchical_parity,
           "single": test_single_leaf}
    if which == "all":
        for fn in fns.values():
            fn()
    else:
        fns[which]()
    print("OK")
