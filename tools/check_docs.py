"""Docs CI checks (run from the repo root):

  1. every relative markdown link in README.md and docs/*.md resolves to
     an existing file/directory;
  2. every registry-registered component name (compressors, transports,
     dispatch policies, corrections, schedules — aliases included)
     appears in docs/spec_grammar.md.

Usage: PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:")


def check_links() -> list[str]:
    errors = []
    pages = [os.path.join(ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(ROOT, "docs", "*.md")))
    for page in pages:
        with open(page) as f:
            text = f.read()
        for target in LINK_RE.findall(text):
            if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
                continue
            path = os.path.normpath(
                os.path.join(os.path.dirname(page), target.split("#")[0]))
            if not os.path.exists(path):
                rel = os.path.relpath(page, ROOT)
                errors.append(f"{rel}: broken link -> {target}")
    return errors


def check_spec_grammar() -> list[str]:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    import repro.core  # noqa: F401 (triggers all registrations)
    from repro.core import registry

    with open(os.path.join(ROOT, "docs", "spec_grammar.md")) as f:
        grammar = f.read()
    errors = []
    for kind in (registry.COMPRESSOR, registry.TRANSPORT,
                 registry.DISPATCH_POLICY, registry.CORRECTION,
                 registry.SCHEDULE):
        for name in registry.names(kind):
            if f"`{name}`" not in grammar:
                errors.append(
                    f"docs/spec_grammar.md: missing {kind} `{name}`")
    return errors


def main() -> None:
    errors = check_links() + check_spec_grammar()
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        sys.exit(1)
    print("OK docs: links resolve, spec grammar covers the registry")


if __name__ == "__main__":
    main()
