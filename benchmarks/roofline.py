"""Roofline analysis (deliverable g): assemble the dry-run records into the
three-term roofline per (arch x shape) on the single-pod mesh.

    compute term    = HLO_FLOPs_per_chip  / 197 TFLOP/s (bf16 peak)
    memory term     = HLO_bytes_per_chip  / 819 GB/s HBM
    collective term = wire_bytes_per_chip / 50 GB/s ICI link

Sources: ``cost_analysis()`` flops / "bytes accessed" are PER-CHIP on this
backend (verified with a calibrated sharded matmul: reported == total/16 on
a 16-way mesh). Collective wire bytes come from the SPMD-partitioned HLO
(per-partition shapes) via launch/hlo_stats.py ring algebra.

Loop-count correction: XLA counts every scan/while body ONCE, so the
production lower undercounts layers and chunk loops. The dryrun --calib
records give per-layer-unit costs from loop-free 1- and 2-unit lowers;
we extrapolate  corrected = base + sum_u trips_u * unit_u  (see
launch/dryrun.py docstring). Decode pairs are loop-free already.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

CHIPS = {"pod16x16": 256, "pod2x16x16": 512}


# --- analytic params -------------------------------------------------------

def model_params(arch: str) -> tuple[float, float]:
    """(total params, active params) from the full config."""
    import jax
    from repro.configs import get_config
    from repro.models.registry import get_model
    cfg = get_config(arch)
    defs = get_model(cfg).param_defs()
    total = active = 0.0
    flat, _ = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))
    for kp, d in flat:
        n = 1
        for s in d.shape:
            n *= s
        total += n
        path = jax.tree_util.keystr(kp)
        if cfg.num_experts and ("w_gate" in path or "w_up" in path
                                or "w_down" in path) and "ffn" in path:
            active += n * cfg.num_experts_per_tok / cfg.num_experts
        else:
            active += n
    return total, active


def model_flops(arch: str, shape: dict) -> float:
    """6*N_active*D per step (whole job)."""
    _, active = model_params(arch)
    toks = shape["global_batch"] * (shape["seq_len"]
                                    if shape["kind"] != "decode" else 1)
    mult = 6.0 if shape["kind"] == "train" else 2.0   # serve: fwd only
    return mult * active * toks


# --- record assembly -------------------------------------------------------

def _load(out_dir: str):
    recs = {}
    for f in glob.glob(os.path.join(out_dir, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"], r.get("tag", ""))
        recs[key] = r
    return recs


def corrected_terms(recs: dict, arch: str, shape: str,
                    mesh: str = "pod16x16") -> dict | None:
    base_rec = recs.get((arch, shape, mesh, ""))
    if base_rec is None or base_rec.get("status") != "ok":
        return None
    cost = base_rec["cost_analysis"]
    raw = {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "wire": base_rec["collectives"]["total_wire_bytes"],
    }
    # gather calibration units
    units, f1s = {}, {}
    for (a, s, m, tag), r in recs.items():
        if (a, s, m) != (arch, shape, mesh) or not tag.startswith("calib_"):
            continue
        if r.get("status") != "ok":
            continue
        _, unit, n = tag.rsplit("_", 2)
        c = r["cost_analysis"]
        entry = {"flops": c.get("flops", 0.0),
                 "bytes": c.get("bytes accessed", 0.0),
                 "wire": r["collectives"]["total_wire_bytes"],
                 "trips": r.get("trips", 0)}
        if n == "1":
            f1s[unit] = entry
        else:
            units.setdefault(unit, {}).update(
                {k: entry[k] for k in ("flops", "bytes", "wire")})
            units[unit]["trips"] = entry["trips"]

    out = dict(raw)
    out["corrected"] = False
    if units and all(u in f1s for u in units):
        per_unit = {
            u: {k: units[u][k] - f1s[u][k] for k in ("flops", "bytes",
                                                     "wire")}
            for u in units}
        shared = len(f1s) > 1 and all(
            abs(f1s[u]["flops"] - list(f1s.values())[0]["flops"]) < 1e-3
            for u in f1s)
        # base: subtract each unit once from its own f1; for shared-f1
        # families (encdec: one (1enc,1dec) config) subtract ALL units.
        first = next(iter(f1s))
        base = {k: f1s[first][k] - per_unit[first][k]
                for k in ("flops", "bytes", "wire")}
        if shared:
            for u in per_unit:
                if u != first:
                    base = {k: base[k] - per_unit[u][k]
                            for k in base}
        corrected = {}
        for k in ("flops", "bytes", "wire"):
            corrected[k] = base[k] + sum(
                units[u]["trips"] * per_unit[u][k] for u in units)
        # corrected values must never be below the raw production count
        for k in corrected:
            out[k] = max(corrected[k], raw[k])
        out["corrected"] = True
    return out


def bottleneck_advice(dom: str, arch: str, shape: str) -> str:
    if dom == "collective":
        return ("reduce wire bytes: higher compression density dispatch, "
                "quantized messages, or keep TP traffic off the step "
                "critical path")
    if dom == "memory":
        return ("improve arithmetic intensity: fuse elementwise chains, "
                "larger matmul tiles, bf16 intermediates")
    return ("raise MXU utilization: larger per-chip matmul shapes "
            "(less model sharding) or fewer redundant recomputes (remat "
            "policy)")


def build_table(out_dir: str = "experiments/dryrun",
                mesh: str = "pod16x16"):
    from repro.configs import ARCH_IDS, SHAPES
    recs = _load(out_dir)
    chips = CHIPS[mesh]
    rows = []
    for arch in ARCH_IDS:
        for sname, shp in SHAPES.items():
            t = corrected_terms(recs, arch, sname, mesh)
            if t is None:
                rec = recs.get((arch, sname, mesh, ""))
                if rec is not None and rec.get("status") == "skipped":
                    rows.append({"arch": arch, "shape": sname,
                                 "status": "skipped"})
                continue
            shape_d = {"global_batch": shp.global_batch,
                       "seq_len": shp.seq_len, "kind": shp.kind}
            mf = model_flops(arch, shape_d) / chips
            terms = {
                "compute_s": t["flops"] / PEAK_FLOPS,
                "memory_s": t["bytes"] / HBM_BW,
                "collective_s": t["wire"] / ICI_BW,
            }
            dom = max(terms, key=terms.get).replace("_s", "")
            rows.append({
                "arch": arch, "shape": sname, "status": "ok",
                "corrected": t["corrected"],
                **{k: round(v, 6) for k, v in terms.items()},
                "dominant": dom,
                "model_flops_per_chip": mf,
                "useful_ratio": round(mf / max(t["flops"], 1.0), 4),
                "advice": bottleneck_advice(dom, arch, sname),
            })
    return rows


def main(quick: bool = False):
    rows = build_table()
    print("roofline: per (arch x shape), single-pod 16x16 (seconds/step)")
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,corrected")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']},{r['shape']},skipped,,,,,")
            continue
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.5f},"
              f"{r['memory_s']:.5f},{r['collective_s']:.5f},"
              f"{r['dominant']},{r['useful_ratio']},{r['corrected']}")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    print("written experiments/roofline.json")
    return rows


if __name__ == "__main__":
    main()
