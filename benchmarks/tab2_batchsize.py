"""Table 2 reproduction: RGC robustness to batch size.

The paper shows RGC matches (often beats) SGD as the global batch grows
128 -> 2048 on Cifar10. Scaled to this container: batch 8 -> 64 on the
bigram task with the reduced LSTM; claim validated = RGC's held-out loss
stays within tolerance of SGD's at every batch size (no compounding
degradation from sparsification as batches grow).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.data import bigram_batches
from repro.train.trainer import Trainer


def run_bs(arch: str, optimizer: str, batch: int, steps: int, seed=0):
    cfg = get_config(arch, smoke=True)
    tc = TrainConfig(lr=0.5, momentum=0.0, optimizer=optimizer,
                     density=0.01, local_clip=1.0, seed=seed)
    tr = Trainer(cfg, tc)
    state = tr.init_state()
    state = tr.run(state, bigram_batches(cfg.vocab_size, batch, 64,
                                         seed=seed), steps, log_every=0)
    src = bigram_batches(cfg.vocab_size, 16, 64, seed=seed + 1)
    held = next(src)
    return float(tr.model.loss(state.params,
                               {k: jnp.asarray(v) for k, v in held.items()}))


def main(quick: bool = False):
    steps = 40 if quick else 120
    sizes = (8, 16) if quick else (8, 16, 32, 64)
    print("tab2_batchsize: held-out loss vs global batch (paper Tab 2)")
    print("batch,sgd,rgc")
    for bs in sizes:
        sgd = run_bs("paper-lstm", "dense", bs, steps)
        rgc = run_bs("paper-lstm", "rgc", bs, steps)
        print(f"{bs},{sgd:.4f},{rgc:.4f}")
        assert rgc < sgd + 0.35, f"batch {bs}: RGC degraded vs SGD"
    print("claims: OK (no compounding RGC degradation with batch size)")


if __name__ == "__main__":
    main()
