"""Fig 10 reproduction: where RGC time goes as p scales.

The paper decomposes a RedSync iteration into mask / select / pack /
transfer / unpack and shows the UNPACK (decompression) share exploding
with p — 67-69% of step time for ResNet50 at 128 GPUs — because the
gathered message count grows linearly with p (the p·γ1 term of Eq 1).

We reproduce the decomposition two ways:
  1. modeled: Eq 1 term-by-term (``cost_model.predicted_shares`` — the
     same term definitions fig7 scales) for the paper's ResNet50/VGG16
     sizes.
  2. measured: decompression wall time with the gathered message count
     scaled artificially to p workers — demonstrating the linear-unpack
     growth with real code. The per-stage (mask/select/pack/transfer/
     unpack) measurement of the REAL ``GradientSync.update`` pipeline
     lives in ``benchmarks/bench_transport.py`` (BENCH_transport.json).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import registry
from repro.core.cost_model import PIZ_DAINT, predicted_shares
from repro.core.residual import init_leaf


def modeled_shares(size_mb: float, p: int, density=0.001, net=PIZ_DAINT):
    """Eq 1 stage shares via the shared cost model; the selection time
    derives from the model size (``t_select_model``'s one-scan rate)
    instead of a hard-coded constant, so a 528 MB VGG16 no longer reports
    the same absolute select cost as a 103 MB ResNet50."""
    m = size_mb * 1024 * 1024 // 4
    return predicted_shares(p, m, density, net)


def measured_unpack_growth(n=4_000_000, density=0.001,
                           ps=(2, 8, 32, 128), iters=3):
    """Real-code decompression cost vs worker count."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    k = max(1, int(n * density))
    comp = registry.make(registry.COMPRESSOR, "trimmed_topk")
    transport = registry.make(registry.TRANSPORT, "fused_allgather")
    s, _ = comp.compress(x, k, init_leaf(x, momentum=False))
    msg = transport.pack(s, comp.quantized)
    rows = []
    for p in ps:
        gathered = jnp.tile(msg[None], (p, 1))
        f = jax.jit(lambda g: comp.decompress(g, n, k))
        jax.block_until_ready(f(gathered))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(f(gathered))
        rows.append({"p": p, "unpack_ms": (time.perf_counter() - t0)
                     / iters * 1e3})
    return rows


def main(quick: bool = False):
    print("fig10_decomposition: modeled share of step time (Eq 1 terms)")
    print("model,p,select_share,transfer_share,unpack_share")
    for name, mb in (("resnet50", 103), ("vgg16", 528)):
        for p in (8, 32, 128):
            sh = modeled_shares(mb, p)
            print(f"{name},{p},{sh['select']:.3f},{sh['transfer']:.3f},"
                  f"{sh['unpack']:.3f}")
    print("measured: decompression wall time vs p (real scatter-add)")
    rows = measured_unpack_growth(n=400_000 if quick else 4_000_000,
                                  ps=(2, 8, 32) if quick else (2, 8, 32, 128))
    print("p,unpack_ms")
    for r in rows:
        print(f"{r['p']},{r['unpack_ms']:.3f}")
    # growth claim: the MARGINAL unpack cost grows ~linearly with p (the
    # dense-buffer init is a fixed floor, so compare against the p=2 base)
    base = rows[0]["unpack_ms"]
    d_mid = rows[1]["unpack_ms"] - base
    d_end = rows[-1]["unpack_ms"] - base
    assert d_end > 2.0 * max(d_mid, 1e-6) or d_end > base
    print("claims: OK (unpack grows ~linearly with p; dominates at scale)")


if __name__ == "__main__":
    main()
