"""Fig 7/8/9 reproduction: scalability of RGC / Quantized-RGC vs dense
allreduce, via the paper's cost model (Eq 1 / Eq 2, §5.5) extended with the
§5.6 overlap rules and a per-message decompression launch overhead (the
paper's Fig 10 "unpack" term that dominates ResNet50 at 128 GPUs).

Per-iteration model:
  compute   t_comp = 3 * fwd_GFlop * batch / (14 TFLOP/s * 33% MFU)
  dense     comm = Eq 2; CNNs overlap layer-wise with backprop (§5.6) ->
            hidden = min(comm, 0.9 * t_comp); LSTM (BPTT) hides nothing.
  RGC       select+pack (not hideable) + Eq 1 bandwidth term (hideable for
            CNNs) + unpack = p * (n_layers * launch + M*D*gamma1)
            (never hideable: happens after the gather).

Claims validated (paper §6.4):
  * VGG16 / AlexNet / LSTM speed up (1.4x-2x+ at paper scales).
  * ResNet50 shows NO gain at 128 GPUs (paper: 0.66x-0.94x) — killed by
    per-message unpack overhead across its ~50 small compressed layers.
  * weak-scaling efficiency of RGC declines with p (concave Fig 7 curves):
    bandwidth (p-1)*M*D and unpack p*gamma1 grow linearly in p.
"""
from __future__ import annotations

from repro.core.cost_model import MURADIN, PIZ_DAINT, eq1_terms, t_dense

# (name, model MB, fwd GFlop/sample, batch/node, compressed layer count)
MODELS = {
    "alexnet": (233, 0.72, 32, 8),
    "vgg16": (528, 15.5, 32, 16),
    "resnet50": (103, 8.22, 32, 50),
    "lstm-ptb": (264, 2.52, 5, 4),
}
GPU_FLOPS_EFF = 14e12 * 0.33
T_SELECT_PER_LAYER = 2e-4        # Fig 3 scale: trimmed top-k on GPU
UNPACK_LAUNCH = 1e-5             # per gathered message scatter-add launch


def step_time(name: str, p: int, mode: str, net, density=0.001) -> float:
    size_mb, gflop, bs, n_layers = MODELS[name]
    m = size_mb * 1024 * 1024 // 4
    t_comp = 3 * gflop * 1e9 * bs / GPU_FLOPS_EFF
    cnn = name != "lstm-ptb"

    if mode == "dense":
        comm = t_dense(p, m, net)
        hidden = min(comm, 0.9 * t_comp) if cnn else 0.0
        return t_comp + comm - hidden

    # Eq 1 terms from the shared cost model; fig7 adds its per-layer
    # overheads on top (selection launch per layer, scatter-add launch
    # per gathered message) and the §5.6 overlap rule
    terms = eq1_terms(p, m, density, net,
                      t_select=n_layers * T_SELECT_PER_LAYER,
                      quantized=(mode == "quant"))
    t_bw = terms["bandwidth"]
    hidden = min(t_bw, 0.9 * t_comp) if cnn else 0.0
    t_unpack = p * n_layers * UNPACK_LAUNCH + terms["unpack"]
    return (t_comp + terms["select"] + terms["latency"]
            + (t_bw - hidden) + t_unpack)


def speedup_vs_dense(name: str, p: int, mode: str, net) -> float:
    return step_time(name, p, "dense", net) / step_time(name, p, mode, net)


def run(net=PIZ_DAINT, ps=(2, 4, 8, 16, 32, 64, 128)):
    rows = []
    for name in MODELS:
        for p in ps:
            rows.append({
                "model": name, "p": p, "net": net.name,
                "speedup_rgc": speedup_vs_dense(name, p, "rgc", net),
                "speedup_quant": speedup_vs_dense(name, p, "quant", net),
            })
    return rows


def main(quick: bool = False):
    print("fig7_scalability: modeled RGC speedup vs dense (Eq1/Eq2 + §5.6)")
    print("model,p,net,speedup_rgc,speedup_quant")
    for net in (PIZ_DAINT, MURADIN):
        for r in run(net=net):
            print(f"{r['model']},{r['p']},{r['net']},"
                  f"{r['speedup_rgc']:.3f},{r['speedup_quant']:.3f}")
    # paper §6.4 claims
    assert speedup_vs_dense("vgg16", 128, "quant", PIZ_DAINT) > 1.2
    assert speedup_vs_dense("alexnet", 32, "quant", PIZ_DAINT) > 1.2
    assert speedup_vs_dense("lstm-ptb", 8, "rgc", PIZ_DAINT) > 1.5
    assert speedup_vs_dense("resnet50", 128, "quant", PIZ_DAINT) <= 1.05
    # quantization halves the bandwidth term -> quant >= plain for CNNs
    assert (speedup_vs_dense("vgg16", 128, "quant", PIZ_DAINT)
            >= speedup_vs_dense("vgg16", 128, "rgc", PIZ_DAINT))
    # concave weak-scaling: RGC step time grows with p
    ts = {p: step_time("lstm-ptb", p, "rgc", PIZ_DAINT)
          for p in (8, 128, 1024)}
    assert ts[1024] > ts[128] > ts[8]
    print("claims: OK (vgg/alexnet/lstm speedup, resnet50 no-gain, "
          "quant>=rgc, concave scaling)")


if __name__ == "__main__":
    main()
