"""Fig 3 reproduction: communication-set selection cost vs parameter size.

The paper compares radixSelect (exact top-k) against trimmed top-k and
threshold binary search on GPU for 1 MB – 64 MB parameter arrays at
D = 0.1%. We measure the same four methods (exact ``lax.top_k`` is the
radixSelect stand-in) as jit-compiled wall time on this host, plus the
modeled allreduce time for the same bytes ("Comm." line of Fig 3).

Paper claim validated: both RedSync selectors beat exact top-k by a
growing margin as the array grows (paper: 38.1x / 16.2x at 64 MB on GPU);
the CPU backend reproduces the ordering and the growth trend, not the GPU
constants (documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import registry
from repro.core.cost_model import MURADIN
from repro.core.residual import init_leaf


def _time(fn, *args, iters=5) -> float:
    fn(*args)                      # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def _time_compressor(name: str, x: jax.Array, k: int, iters: int) -> float:
    """Time one registry compressor's compress() on a fresh leaf state.

    A fresh state has interval == 0, so threshold_bsearch always takes the
    refresh (full binary search) branch — the cost Fig 3 measures.
    """
    comp = registry.make(registry.COMPRESSOR, name)
    st = init_leaf(x, momentum=False)
    return _time(jax.jit(lambda v, s: comp.compress(v, k, s)), x, st,
                 iters=iters)


def run(sizes_mb=(1, 4, 16, 64), density=0.001, iters=5):
    rows = []
    for mb in sizes_mb:
        n = mb * 1024 * 1024 // 4
        k = max(1, int(n * density))
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                        jnp.float32)
        t_exact = _time_compressor("exact_topk", x, k, iters)
        t_trim = _time_compressor("trimmed_topk", x, k, iters)
        t_bs = _time_compressor("threshold_bsearch", x, k, iters)
        t_comm = n * 4 / MURADIN.bandwidth          # Fig 3 "Comm." line
        rows.append({
            "size_mb": mb, "k": k,
            "exact_topk_ms": t_exact * 1e3,
            "trimmed_ms": t_trim * 1e3,
            "bsearch_ms": t_bs * 1e3,
            "comm_3.5GBps_ms": t_comm * 1e3,
            "speedup_trimmed": t_exact / t_trim,
            "speedup_bsearch": t_exact / t_bs,
        })
    return rows


def main(quick: bool = False):
    rows = run(sizes_mb=(1, 4) if quick else (1, 4, 16, 64),
               iters=3 if quick else 5)
    print("fig3_selection: method time vs parameter size (D=0.1%)")
    hdr = ("size_mb", "exact_topk_ms", "trimmed_ms", "bsearch_ms",
           "comm_3.5GBps_ms", "speedup_trimmed", "speedup_bsearch")
    print(",".join(hdr))
    for r in rows:
        print(",".join(f"{r[h]:.3f}" if isinstance(r[h], float)
                       else str(r[h]) for h in hdr))
    return rows


if __name__ == "__main__":
    main()
