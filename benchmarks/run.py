"""Benchmark orchestrator: one module per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--full]

Default is quick mode (CPU-friendly budgets). Each module prints CSV and
asserts its paper-claim checks; failures propagate as nonzero exit.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    args = ap.parse_args()
    quick = not args.full

    from . import (bench_transport, fig3_selection, fig7_scalability,
                   fig10_decomposition, roofline, tab1_convergence,
                   tab2_batchsize)
    mods = {
        "fig3": fig3_selection, "fig7": fig7_scalability,
        "fig10": fig10_decomposition, "tab1": tab1_convergence,
        "tab2": tab2_batchsize, "roofline": roofline,
        "transport": bench_transport,
    }
    chosen = (args.only.split(",") if args.only else list(mods))
    failures = []
    for name in chosen:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            mods[name].main(quick=quick)
            print(f"[{name}] done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"[{name}] FAILED: {e!r}")
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
