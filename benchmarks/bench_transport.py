"""Measured Fig 10 stage decomposition on the REAL sync pipeline.

Runs ``GradientSync.update`` EAGERLY (op-by-op, no jit) with a
``WallClockTimer`` threaded through the pipeline and the transport, so
every stage of the paper's decomposition — accumulate + mask (Fig 10's
"mask" bar, split), select, pack, transfer, unpack — is timed with a
device barrier, per transport backend. This replaces fig10's artificial
stage loop with the exact code path the trainer runs.

Two comparison axes:

* ``per_transport`` — the §5.3/§5.4 collective backends, measured on the
  historical PER-LEAF pipeline (``fuse_leaves=False``) so collective
  counts stay a function of the leaf set;
* ``arena_vs_per_leaf`` — the flat residual arenas (``fuse_leaves``, the
  default) against that per-leaf baseline on the fused transport:
  per-stage wall time, ``dispatch_<stage>`` fused-operation counts and
  collective/message counts. The claim asserts encode the arena
  contract: select/mask/pack dispatches drop from O(leaves) to
  O(arenas), collectives never increase, and fused mask+select+pack wall
  time is no worse than per-leaf.

A ``selection_attack`` axis gates the selection-cost work: all-Alg 3
trees measured per-leaf cold-search (the historical bottleneck, select
at ~85% of the overhead stages) vs warm-started bisection, the single
fused multi-arena select launch, and sampled statistics/counting
(``sampled_bsearch``) — with hard asserts that the fused variants issue
ONE select dispatch per step and land strictly below the baseline share.

A third axis measures the §5.6 overlap scheduler for real
(``measured_overlap``): the ``chunked`` schedule (reverse-parameter-order
chunk pipelining, ``repro.core.overlap``) against the ``sequential``
full-tree barrier — per-schedule collective counts (chunked must issue
>= 2 transport dispatches per step; one barrier is a silent fallback and
fails the claim asserts), per-chunk stage lanes, and an END-TO-END
eager wall-clock comparison run WITHOUT per-stage barriers (those would
serialize the dispatch overlap being measured). Chunked must be no
slower than sequential; measured runs at p=1 come out faster (observed
1.04x–1.9x on this container depending on load — non-blocking dispatch
overlaps an issued chunk's execution with the next chunk's issue; the
deterministic dispatch-count asserts are the primary gate).

Single-process eager execution means ``sync_axes=()`` (p=1): the
``transfer`` stage measures the backend's buffer plumbing (concat/split,
bucket walk), not wire time — so the Eq 1 predicted decomposition for the
paper's testbeds at real worker counts is emitted alongside
(``cost_model.predicted_shares``), plus the §5.6 comm/compute overlap
headroom against a measured smoke-model backprop (the modeled companion
to ``measured_overlap``). Emits ``BENCH_transport.json`` (uploaded as a
CI artifact by the tier-2 job).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.core import WallClockTimer
from repro.core.cost_model import (DENSE_THRESHOLD_BYTES, PIZ_DAINT,
                                   TPU_V5E, eq1_terms, predicted_shares)
from repro.train.trainer import make_gradient_sync

# VGG-flavoured mixed-size tree: a few big sparse leaves (threshold
# bsearch), several mid leaves (trimmed top-k), many small dense leaves —
# all three §5.5 dispatch classes in one step.
FULL_TREE = {
    "fc6": 4_194_304 + 11, "fc7": 2_097_152 + 7, "conv5": 1_048_576 + 3,
    "conv4": 524_288 + 1, "conv3": 262_144, "conv2": 98_304,
    "conv1": 49_152, "bias1": 4_096, "bias2": 1_000, "bias3": 512,
}
QUICK_TREE = {
    "fc6": 1_048_576 + 11, "conv5": 262_144 + 3, "conv4": 98_304,
    "conv2": 49_152, "bias1": 4_096, "bias2": 512,
}

TRANSPORTS = ("fused_allgather", "bucketed_allgather", "per_leaf_allgather",
              "hierarchical")
DENSITY = 0.001
WORKER_COUNTS = (8, 32, 128)


def make_tree(sizes: dict[str, int]):
    rng = np.random.default_rng(0)
    params = {k: jnp.asarray(rng.standard_normal(n), jnp.float32)
              for k, n in sizes.items()}
    grads = {k: jnp.asarray(rng.standard_normal(n) * 0.01, jnp.float32)
             for k, n in sizes.items()}
    return params, grads


def measure_transport(name: str, params, grads, *, iters: int,
                      bucket_bytes: int, fuse_leaves: bool = False,
                      schedule: str = "sequential") -> dict:
    """Per-stage wall time of eager ``GradientSync.update`` steps.

    Built through the trainer's ``make_gradient_sync`` (mesh=None ->
    ``sync_axes=()``) so the measured pipeline is exactly what a
    TrainConfig with this transport would run, timer hook included.
    ``fuse_leaves=False`` is the per-leaf baseline; True measures the
    flat-arena pipeline. ``schedule`` picks the §5.6 overlap scheduler
    (the ``chunked`` run records per-chunk stage lanes).
    """
    timer = WallClockTimer()
    tc = TrainConfig(optimizer="rgc", transport=name, density=DENSITY,
                     momentum=0.9, bucket_bytes=bucket_bytes,
                     fuse_leaves=fuse_leaves, schedule=schedule)
    sync = make_gradient_sync(tc, None, timer=timer)
    state = sync.init(params)
    # warmup step (allocator, first-touch) outside the measurement
    _, state = sync.update(grads, state, params, jnp.float32(0.1))
    timer.reset()
    p = params
    for _ in range(iters):
        p, state = sync.update(grads, state, p, jnp.float32(0.1))
    out = timer.summary()
    out["iters"] = iters
    return out


def measure_compute(iters: int = 3) -> float:
    """Eager backprop wall time of a real smoke model (the overlap
    budget of §5.6 — what layer-wise scheduling could hide comm behind)."""
    from repro.configs import get_config
    from repro.models.registry import get_model

    model = get_model(get_config("paper-lstm", smoke=True))
    params = model.init_params(0)
    batch = model.make_train_batch(8, 32)
    grad_fn = jax.value_and_grad(model.loss)
    jax.block_until_ready(grad_fn(params, batch))      # warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(grad_fn(params, batch))
    return (time.perf_counter() - t0) / iters


def overlap_report(m_elems: int, t_compute: float, net=PIZ_DAINT) -> dict:
    """§5.6 headroom: which share of the Eq 1 bandwidth term layer-wise
    overlap could hide behind a backprop of the measured length."""
    per_p = {}
    for p in WORKER_COUNTS:
        terms = eq1_terms(p, m_elems, DENSITY, net)
        bw = terms["bandwidth"]
        hidden = min(0.9 * t_compute, bw)
        per_p[str(p)] = {
            "bandwidth_s": bw,
            "hidden_s": hidden,
            "hidden_share": hidden / bw if bw > 0 else 1.0,
            "exposed_s": bw - hidden,
        }
    return {"t_compute_s": t_compute, "net": net.name, "per_p": per_p}


def measure_schedule_wall(schedule: str, params, grads, *, steps: int,
                          repeats: int, chunk_bytes: int) -> float:
    """End-to-end eager wall time per step of one overlap schedule.

    Deliberately run with the free ``NullTimer`` and a SINGLE barrier at
    the end of each measured loop: the per-stage barriers of
    ``WallClockTimer`` would serialize the very dispatch overlap the
    chunked schedule exists to create (jax's non-blocking eager dispatch
    executes an issued chunk's ops while the Python thread issues the
    next chunk's). Best-of-``repeats`` to shed scheduler noise.
    """
    tc = TrainConfig(optimizer="rgc", transport="fused_allgather",
                     density=DENSITY, momentum=0.9, schedule=schedule,
                     bucket_bytes=chunk_bytes)
    sync = make_gradient_sync(tc, None)
    state0 = sync.init(params)
    warm = sync.update(grads, state0, params, jnp.float32(0.1))
    jax.block_until_ready(warm)
    best = float("inf")
    for _ in range(repeats):
        p, st = params, state0
        t0 = time.perf_counter()
        for _ in range(steps):
            p, st = sync.update(grads, st, p, jnp.float32(0.1))
        jax.block_until_ready((p, st))
        best = min(best, (time.perf_counter() - t0) / steps)
    return best


def measured_overlap(params, grads, *, iters: int, chunk_bytes: int,
                     overlap: dict, candidate: str = "chunked") -> dict:
    """§5.6 MEASURED: sequential vs chunked on the real pipeline.

    Two measurements per schedule on the fused transport:

    * a ``WallClockTimer`` stage run (per-stage attribution under the
      Fig 10 names; the chunked run additionally carries per-chunk
      ``lanes``) — this is where the dispatch accounting comes from:
      sequential must issue exactly ONE collective per step, chunked at
      least two (one per chunk carrying sparse messages — the "no
      silent fallback to one barrier" gate);
    * an end-to-end wall-clock run (``measure_schedule_wall``, single
      barrier per loop) — the §5.6 claim itself: pipelined per-chunk
      dispatch is no slower (and measured faster) than the full-tree
      barrier even at p=1, because eager dispatch overlaps an issued
      chunk's execution with the next chunk's issue. The
      ``overlap_report`` headroom model rides along as ``modeled`` for
      comparison against Eq 1's wire-time story.
    """
    per: dict[str, dict] = {}
    for sched in ("sequential", candidate):
        timed = measure_transport(
            "fused_allgather", params, grads, iters=iters,
            bucket_bytes=chunk_bytes, schedule=sched)
        wall = measure_schedule_wall(sched, params, grads,
                                     steps=max(2, iters // 2), repeats=3,
                                     chunk_bytes=chunk_bytes)
        per[sched] = {
            "stages": timed["stages"],
            "counts": timed["counts"],
            "lanes": timed.get("lanes", {}),
            "collectives_per_step":
                timed["counts"].get("collectives", 0) / timed["iters"],
            "wall_s_per_step": wall,
        }
    return {
        "candidate": candidate,
        "chunk_bytes": chunk_bytes,
        "n_chunks": len(per[candidate]["lanes"]) or None,
        "per_schedule": per,
        "speedup": (per["sequential"]["wall_s_per_step"]
                    / per[candidate]["wall_s_per_step"]),
        "modeled": overlap["per_p"],
    }


FUSED_STAGES = ("mask", "select", "pack")     # the O(arenas) claim set

# The historical select bottleneck: on the per-leaf cold-search pipeline
# select's share of the Fig 10 overhead stages (accumulate + select +
# mask + pack) measures >= ~85% (the ROADMAP "kill the selection
# bottleneck" figure; 0.93 on this container's quick mode, where the
# non-select stages are cheap).  The gate is two-sided: the in-run
# per_leaf_cold share must come in AT LEAST this high — otherwise the
# bottleneck claim itself is stale and there is nothing to attack — and
# the attacked pipeline (ONE fused multi-arena select per step,
# warm-started bisection, sampled counting) must land strictly below
# that measured baseline in both share and select wall time. A constant
# (not read back from a previous BENCH_transport.json — the JSON is a
# generated artifact).
SELECT_BASELINE_SHARE = 0.85

OVERHEAD_STAGES = ("accumulate", "select", "mask", "pack")


def _select_share(summary: dict) -> float:
    """select's share of the summed overhead-stage wall time."""
    tot = sum(summary["stages"][s]["total_s"]
              for s in OVERHEAD_STAGES if s in summary["stages"])
    sel = summary["stages"].get("select", {}).get("total_s", 0.0)
    return sel / tot if tot > 0 else 0.0


def selection_attack(params, grads, *, iters: int) -> dict:
    """The selection-cost attack: sampled statistics, warm-started
    bisection and the single fused multi-arena select launch, against
    the historical per-leaf cold-search pipeline.

    Every variant routes ALL sparse leaves through Alg 3 (the selector
    the attack targets); the dispatch counter records select launches
    (one per leaf cold -> ONE per step fused) and ``select_overflow``
    surfaces pinned capacity overflows of the threshold filter.
    """
    from repro.core import build_gradient_sync

    variants = {
        # the bottleneck being attacked: per-leaf, cold re-search
        "per_leaf_cold": dict(optimizer="threshold_bsearch",
                              fuse_leaves=False, warm_start=False),
        # warm-started bisection alone, still one launch per leaf
        "per_leaf_warm": dict(optimizer="threshold_bsearch",
                              fuse_leaves=False, warm_start=True),
        # + the single fused multi-arena select launch per step
        "fused_warm": dict(optimizer="threshold_bsearch",
                           fuse_leaves=True, warm_start=True),
        # the full attack: + sampled statistics / sampled nnz counting
        "fused_warm_sampled": dict(optimizer="sampled_bsearch",
                                   fuse_leaves=True, warm_start=True,
                                   sampled_tolerance=0.5),
    }
    out: dict[str, dict] = {}
    for label, kw in variants.items():
        timer = WallClockTimer()
        sync = build_gradient_sync(
            transport="fused_allgather", density=DENSITY, momentum=0.9,
            timer=timer, **kw)
        state = sync.init(params)
        _, state = sync.update(grads, state, params, jnp.float32(0.1))
        timer.reset()
        p = params
        for _ in range(iters):
            p, state = sync.update(grads, state, p, jnp.float32(0.1))
        summ = timer.summary()
        out[label] = {
            "stages": summ["stages"],
            "counts": summ["counts"],
            "select_share": _select_share(summ),
            "select_total_s": summ["stages"]["select"]["total_s"],
            "select_dispatches_per_step":
                summ["counts"].get("dispatch_select", 0) / iters,
            "select_overflow": summ["counts"].get("select_overflow", 0),
        }
    return {"iters": iters, "baseline_share": SELECT_BASELINE_SHARE,
            "variants": out}


def arena_vs_per_leaf(params, grads, *, iters: int,
                      bucket_bytes: int) -> dict:
    """Flat arenas vs per-leaf pipeline on the fused transport.

    Returns per-mode stage summaries plus the dispatch/collective count
    comparison the tier-2 CI asserts on.
    """
    modes = {}
    for label, fuse in (("per_leaf", False), ("arena", True)):
        modes[label] = measure_transport(
            "fused_allgather", params, grads, iters=iters,
            bucket_bytes=bucket_bytes, fuse_leaves=fuse)

    def fused_wall(mode):
        return sum(modes[mode]["stages"][s]["total_s"]
                   for s in FUSED_STAGES)

    cmp = {
        "dispatch_counts": {
            mode: {k: v for k, v in modes[mode]["counts"].items()
                   if k.startswith("dispatch_")}
            for mode in modes},
        "collectives": {m: modes[m]["counts"].get("collectives", 0)
                        for m in modes},
        "messages": {m: modes[m]["counts"].get("messages", 0)
                     for m in modes},
        "fused_stage_wall_s": {m: fused_wall(m) for m in modes},
    }
    return {"modes": modes, "comparison": cmp}


def main(quick: bool = False, schedule: str = "chunked") -> dict:
    sizes = QUICK_TREE if quick else FULL_TREE
    iters = 2 if quick else 5
    # budget sized against the PACKED messages (density * 0.1% of the
    # tree), not the raw leaves — small enough that the message set
    # splits into several buckets per step
    bucket_bytes = 8_192 if quick else 32_768
    params, grads = make_tree(sizes)
    m_total = sum(sizes.values())
    print(f"bench_transport: {len(sizes)} leaves, "
          f"{m_total * 4 / 2**20:.1f} MB, density {DENSITY}, "
          f"{iters} eager iterations per transport")

    per_transport = {}
    print("transport,stage,mean_ms,share,calls")
    for name in TRANSPORTS:
        summ = measure_transport(name, params, grads, iters=iters,
                                 bucket_bytes=bucket_bytes)
        per_transport[name] = summ
        for stage, s in summ["stages"].items():
            print(f"{name},{stage},{s['mean_ms']:.3f},{s['share']:.3f},"
                  f"{s['calls']}")

    arena_cmp = arena_vs_per_leaf(params, grads, iters=iters,
                                  bucket_bytes=bucket_bytes)
    cmp = arena_cmp["comparison"]
    print("arena_vs_per_leaf,metric,per_leaf,arena")
    for stage in ("accumulate",) + FUSED_STAGES:
        key = f"dispatch_{stage}"
        print(f"arena_vs_per_leaf,{key},"
              f"{cmp['dispatch_counts']['per_leaf'].get(key, 0)},"
              f"{cmp['dispatch_counts']['arena'].get(key, 0)}")
    print(f"arena_vs_per_leaf,collectives,{cmp['collectives']['per_leaf']},"
          f"{cmp['collectives']['arena']}")
    print(f"arena_vs_per_leaf,mask+select+pack_s,"
          f"{cmp['fused_stage_wall_s']['per_leaf']:.4f},"
          f"{cmp['fused_stage_wall_s']['arena']:.4f}")

    attack = selection_attack(params, grads, iters=iters)
    print("selection_attack,variant,select_share,select_ms,"
          "select_dispatches_per_step,select_overflow")
    for label, row in attack["variants"].items():
        print(f"selection_attack,{label},{row['select_share']:.3f},"
              f"{row['select_total_s'] * 1e3:.2f},"
              f"{row['select_dispatches_per_step']:.1f},"
              f"{row['select_overflow']}")

    predicted = {}
    for net in (PIZ_DAINT, TPU_V5E):
        predicted[net.name] = {
            str(p): predicted_shares(p, m_total, DENSITY, net)
            for p in WORKER_COUNTS}

    t_comp = measure_compute(iters=1 if quick else 3)
    overlap = overlap_report(m_total, t_comp)

    # §5.6 measured: sequential barrier vs chunked pipelined dispatch.
    # The chunk budget is the default 4 MiB gradient-byte budget (NOT the
    # packed-message budget above): it must split the RAW tree so the
    # step really issues several collectives.
    chunk_bytes = 4 * 1024 * 1024
    m_overlap = measured_overlap(params, grads, iters=iters,
                                 chunk_bytes=chunk_bytes, overlap=overlap,
                                 candidate=schedule)
    print("measured_overlap,schedule,collectives_per_step,wall_ms_per_step")
    for sched, row in m_overlap["per_schedule"].items():
        print(f"measured_overlap,{sched},{row['collectives_per_step']:.1f},"
              f"{row['wall_s_per_step'] * 1e3:.2f}")
    print(f"measured_overlap,speedup,{m_overlap['speedup']:.3f},-")

    report = {
        "mode": "quick" if quick else "full",
        "tree": {"leaves": sizes, "total_elems": m_total,
                 "total_mb": m_total * 4 / 2**20, "density": DENSITY,
                 "bucket_bytes": bucket_bytes},
        "per_transport": per_transport,
        "arena_vs_per_leaf": arena_cmp,
        "dispatch_counts": cmp["dispatch_counts"],
        "selection_attack": attack,
        "predicted": predicted,
        "overlap": overlap,
        "measured_overlap": m_overlap,
    }
    out_path = os.path.join(os.getcwd(), "BENCH_transport.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}")

    # claims: every sparse transport exercises the full stage decomposition
    for name in TRANSPORTS:
        stages = per_transport[name]["stages"]
        for stage in ("accumulate", "mask", "select", "pack", "transfer",
                      "unpack"):
            assert stage in stages and stages[stage]["total_s"] > 0, \
                f"{name} missing stage {stage}"
    # bucketing actually bucketed (several collectives per step), while
    # fused stayed at one per step
    n_sparse = sum(1 for s in sizes.values()
                   if s * 4 >= DENSE_THRESHOLD_BYTES)
    assert per_transport["bucketed_allgather"]["counts"]["buckets"] \
        > iters, "bucket budget did not split the message set"
    assert per_transport["fused_allgather"]["counts"]["collectives"] == iters
    assert per_transport["per_leaf_allgather"]["counts"]["collectives"] \
        == iters * n_sparse
    # selection dominates pack at p=1 (pack is a concat; select is a scan)
    fused = per_transport["fused_allgather"]["stages"]
    assert fused["select"]["total_s"] > fused["pack"]["total_s"]

    # flat-arena claims (the tier-2 CI gate): select/mask/pack fused
    # dispatches drop from O(leaves) to O(arenas) — strictly fewer — with
    # no more collectives, and the fused stages' wall time is no worse
    for stage in FUSED_STAGES:
        key = f"dispatch_{stage}"
        assert cmp["dispatch_counts"]["arena"][key] \
            < cmp["dispatch_counts"]["per_leaf"][key], \
            f"arena did not reduce {key}"
    assert cmp["collectives"]["arena"] <= cmp["collectives"]["per_leaf"]
    assert cmp["messages"]["arena"] < cmp["messages"]["per_leaf"]
    # wall time: the dispatch asserts above are the deterministic
    # O(arenas) gate; the timing check keeps a noise margin so a loaded
    # CI runner cannot flake it (exact numbers ride in the JSON)
    assert cmp["fused_stage_wall_s"]["arena"] \
        <= 1.2 * cmp["fused_stage_wall_s"]["per_leaf"], \
        "arena mask+select+pack wall time regressed vs per-leaf"

    # selection-attack claims (the tentpole's tier-2 CI gate): the fused
    # variants issue exactly ONE select dispatch per step (the whole
    # step's arenas search in one multi_select), and the attacked select
    # share of the overhead stages lands strictly below the historical
    # ~85% per-leaf cold-search baseline — a HARD measured drop, with
    # the in-run per_leaf_cold share recorded alongside for context
    av = attack["variants"]
    for label in ("fused_warm", "fused_warm_sampled"):
        assert av[label]["select_dispatches_per_step"] == 1, \
            f"{label} did not fuse select into one dispatch per step"
    cold_share = av["per_leaf_cold"]["select_share"]
    assert cold_share >= SELECT_BASELINE_SHARE, \
        (f"per_leaf_cold select share {cold_share:.3f} came in under the "
         f"historical ~{SELECT_BASELINE_SHARE:.0%} bottleneck figure — "
         f"the attack has no baseline to beat")
    for label in ("fused_warm", "fused_warm_sampled"):
        assert av[label]["select_share"] < cold_share, \
            (f"{label} select share {av[label]['select_share']:.3f} did "
             f"not drop below the measured {cold_share:.3f} cold baseline")
        # the share drop above is the strict (load-insensitive ratio)
        # gate; the raw wall comparison keeps the same noise margin as
        # the arena/overlap gates so a loaded CI runner cannot flake it
        # (idle runs here measure 0.45x-0.62x; exact numbers in the JSON)
        assert av[label]["select_total_s"] \
            <= 1.2 * av["per_leaf_cold"]["select_total_s"], \
            f"{label} select wall time regressed vs the cold baseline"

    # §5.6 measured-overlap claims (the tier-2 CI gate): the chunked
    # schedule must REALLY pipeline — at least two transport dispatches
    # per step, never a silent fallback to one barrier — while the
    # sequential baseline stays at exactly one fused collective. The
    # dispatch asserts are the deterministic gate; the wall-time check
    # keeps the same noise margin as the arena gate above so a loaded
    # CI runner cannot flake it (measured best-of-repeats has come out
    # below 1.0x on every idle run here — 1.04x–1.9x faster depending
    # on load; exact numbers ride in the JSON)
    mo = m_overlap["per_schedule"]
    assert mo["sequential"]["collectives_per_step"] == 1
    assert mo[schedule]["collectives_per_step"] >= 2, \
        f"{schedule} schedule fell back to a single transport barrier"
    assert len(mo[schedule]["lanes"]) >= 2, \
        f"{schedule} schedule recorded no per-chunk stage lanes"
    assert mo[schedule]["wall_s_per_step"] \
        <= 1.1 * mo["sequential"]["wall_s_per_step"], \
        (f"{schedule} step time regressed vs sequential: "
         f"{mo[schedule]['wall_s_per_step']:.4f}s vs "
         f"{mo['sequential']['wall_s_per_step']:.4f}s")
    print("claims: OK (all stages measured on the real pipeline; "
          "bucketed>1 buckets; fused=1 collective/step; arena "
          "mask/select/pack dispatches O(arenas) and no slower; select "
          "fused to 1 dispatch/step with share and wall time below the "
          f"measured >={SELECT_BASELINE_SHARE} cold-search baseline; chunked "
          ">=2 dispatches/step and end-to-end no slower than sequential)")
    return report


if __name__ == "__main__":
    import argparse

    from repro.core import registry as _registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced tree / iteration budgets")
    assert "chunked" in _registry.names(_registry.SCHEDULE)
    ap.add_argument("--schedule", default="chunked", choices=["chunked"],
                    help="pipelined schedule measured against the "
                    "sequential barrier in the measured_overlap section "
                    "(stale1's overlap is cross-step — its cost is "
                    "measured by the tier-2 convergence harness, not "
                    "here)")
    args = ap.parse_args()
    main(quick=args.quick, schedule=args.schedule)
