"""Measured Fig 10 stage decomposition on the REAL sync pipeline.

Runs ``GradientSync.update`` EAGERLY (op-by-op, no jit) with a
``WallClockTimer`` threaded through the pipeline and the transport, so
every stage of the paper's decomposition — accumulate + mask (Fig 10's
"mask" bar, split), select, pack, transfer, unpack — is timed with a
device barrier, per transport backend. This replaces fig10's artificial
stage loop with the exact code path the trainer runs.

Two comparison axes:

* ``per_transport`` — the §5.3/§5.4 collective backends, measured on the
  historical PER-LEAF pipeline (``fuse_leaves=False``) so collective
  counts stay a function of the leaf set;
* ``arena_vs_per_leaf`` — the flat residual arenas (``fuse_leaves``, the
  default) against that per-leaf baseline on the fused transport:
  per-stage wall time, ``dispatch_<stage>`` fused-operation counts and
  collective/message counts. The claim asserts encode the arena
  contract: select/mask/pack dispatches drop from O(leaves) to
  O(arenas), collectives never increase, and fused mask+select+pack wall
  time is no worse than per-leaf.

Single-process eager execution means ``sync_axes=()`` (p=1): the
``transfer`` stage measures the backend's buffer plumbing (concat/split,
bucket walk), not wire time — so the Eq 1 predicted decomposition for the
paper's testbeds at real worker counts is emitted alongside
(``cost_model.predicted_shares``), plus the §5.6 comm/compute overlap
headroom against a measured smoke-model backprop. Emits
``BENCH_transport.json`` (uploaded as a CI artifact by the tier-2 job).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.core import WallClockTimer
from repro.core.cost_model import (DENSE_THRESHOLD_BYTES, PIZ_DAINT,
                                   TPU_V5E, eq1_terms, predicted_shares)
from repro.train.trainer import make_gradient_sync

# VGG-flavoured mixed-size tree: a few big sparse leaves (threshold
# bsearch), several mid leaves (trimmed top-k), many small dense leaves —
# all three §5.5 dispatch classes in one step.
FULL_TREE = {
    "fc6": 4_194_304 + 11, "fc7": 2_097_152 + 7, "conv5": 1_048_576 + 3,
    "conv4": 524_288 + 1, "conv3": 262_144, "conv2": 98_304,
    "conv1": 49_152, "bias1": 4_096, "bias2": 1_000, "bias3": 512,
}
QUICK_TREE = {
    "fc6": 1_048_576 + 11, "conv5": 262_144 + 3, "conv4": 98_304,
    "conv2": 49_152, "bias1": 4_096, "bias2": 512,
}

TRANSPORTS = ("fused_allgather", "bucketed_allgather", "per_leaf_allgather",
              "hierarchical")
DENSITY = 0.001
WORKER_COUNTS = (8, 32, 128)


def make_tree(sizes: dict[str, int]):
    rng = np.random.default_rng(0)
    params = {k: jnp.asarray(rng.standard_normal(n), jnp.float32)
              for k, n in sizes.items()}
    grads = {k: jnp.asarray(rng.standard_normal(n) * 0.01, jnp.float32)
             for k, n in sizes.items()}
    return params, grads


def measure_transport(name: str, params, grads, *, iters: int,
                      bucket_bytes: int, fuse_leaves: bool = False) -> dict:
    """Per-stage wall time of eager ``GradientSync.update`` steps.

    Built through the trainer's ``make_gradient_sync`` (mesh=None ->
    ``sync_axes=()``) so the measured pipeline is exactly what a
    TrainConfig with this transport would run, timer hook included.
    ``fuse_leaves=False`` is the per-leaf baseline; True measures the
    flat-arena pipeline.
    """
    timer = WallClockTimer()
    tc = TrainConfig(optimizer="rgc", transport=name, density=DENSITY,
                     momentum=0.9, bucket_bytes=bucket_bytes,
                     fuse_leaves=fuse_leaves)
    sync = make_gradient_sync(tc, None, timer=timer)
    state = sync.init(params)
    # warmup step (allocator, first-touch) outside the measurement
    _, state = sync.update(grads, state, params, jnp.float32(0.1))
    timer.reset()
    p = params
    for _ in range(iters):
        p, state = sync.update(grads, state, p, jnp.float32(0.1))
    out = timer.summary()
    out["iters"] = iters
    return out


def measure_compute(iters: int = 3) -> float:
    """Eager backprop wall time of a real smoke model (the overlap
    budget of §5.6 — what layer-wise scheduling could hide comm behind)."""
    from repro.configs import get_config
    from repro.models.registry import get_model

    model = get_model(get_config("paper-lstm", smoke=True))
    params = model.init_params(0)
    batch = model.make_train_batch(8, 32)
    grad_fn = jax.value_and_grad(model.loss)
    jax.block_until_ready(grad_fn(params, batch))      # warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(grad_fn(params, batch))
    return (time.perf_counter() - t0) / iters


def overlap_report(m_elems: int, t_compute: float, net=PIZ_DAINT) -> dict:
    """§5.6 headroom: which share of the Eq 1 bandwidth term layer-wise
    overlap could hide behind a backprop of the measured length."""
    per_p = {}
    for p in WORKER_COUNTS:
        terms = eq1_terms(p, m_elems, DENSITY, net)
        bw = terms["bandwidth"]
        hidden = min(0.9 * t_compute, bw)
        per_p[str(p)] = {
            "bandwidth_s": bw,
            "hidden_s": hidden,
            "hidden_share": hidden / bw if bw > 0 else 1.0,
            "exposed_s": bw - hidden,
        }
    return {"t_compute_s": t_compute, "net": net.name, "per_p": per_p}


FUSED_STAGES = ("mask", "select", "pack")     # the O(arenas) claim set


def arena_vs_per_leaf(params, grads, *, iters: int,
                      bucket_bytes: int) -> dict:
    """Flat arenas vs per-leaf pipeline on the fused transport.

    Returns per-mode stage summaries plus the dispatch/collective count
    comparison the tier-2 CI asserts on.
    """
    modes = {}
    for label, fuse in (("per_leaf", False), ("arena", True)):
        modes[label] = measure_transport(
            "fused_allgather", params, grads, iters=iters,
            bucket_bytes=bucket_bytes, fuse_leaves=fuse)

    def fused_wall(mode):
        return sum(modes[mode]["stages"][s]["total_s"]
                   for s in FUSED_STAGES)

    cmp = {
        "dispatch_counts": {
            mode: {k: v for k, v in modes[mode]["counts"].items()
                   if k.startswith("dispatch_")}
            for mode in modes},
        "collectives": {m: modes[m]["counts"].get("collectives", 0)
                        for m in modes},
        "messages": {m: modes[m]["counts"].get("messages", 0)
                     for m in modes},
        "fused_stage_wall_s": {m: fused_wall(m) for m in modes},
    }
    return {"modes": modes, "comparison": cmp}


def main(quick: bool = False) -> dict:
    sizes = QUICK_TREE if quick else FULL_TREE
    iters = 2 if quick else 5
    # budget sized against the PACKED messages (density * 0.1% of the
    # tree), not the raw leaves — small enough that the message set
    # splits into several buckets per step
    bucket_bytes = 8_192 if quick else 32_768
    params, grads = make_tree(sizes)
    m_total = sum(sizes.values())
    print(f"bench_transport: {len(sizes)} leaves, "
          f"{m_total * 4 / 2**20:.1f} MB, density {DENSITY}, "
          f"{iters} eager iterations per transport")

    per_transport = {}
    print("transport,stage,mean_ms,share,calls")
    for name in TRANSPORTS:
        summ = measure_transport(name, params, grads, iters=iters,
                                 bucket_bytes=bucket_bytes)
        per_transport[name] = summ
        for stage, s in summ["stages"].items():
            print(f"{name},{stage},{s['mean_ms']:.3f},{s['share']:.3f},"
                  f"{s['calls']}")

    arena_cmp = arena_vs_per_leaf(params, grads, iters=iters,
                                  bucket_bytes=bucket_bytes)
    cmp = arena_cmp["comparison"]
    print("arena_vs_per_leaf,metric,per_leaf,arena")
    for stage in ("accumulate",) + FUSED_STAGES:
        key = f"dispatch_{stage}"
        print(f"arena_vs_per_leaf,{key},"
              f"{cmp['dispatch_counts']['per_leaf'].get(key, 0)},"
              f"{cmp['dispatch_counts']['arena'].get(key, 0)}")
    print(f"arena_vs_per_leaf,collectives,{cmp['collectives']['per_leaf']},"
          f"{cmp['collectives']['arena']}")
    print(f"arena_vs_per_leaf,mask+select+pack_s,"
          f"{cmp['fused_stage_wall_s']['per_leaf']:.4f},"
          f"{cmp['fused_stage_wall_s']['arena']:.4f}")

    predicted = {}
    for net in (PIZ_DAINT, TPU_V5E):
        predicted[net.name] = {
            str(p): predicted_shares(p, m_total, DENSITY, net)
            for p in WORKER_COUNTS}

    t_comp = measure_compute(iters=1 if quick else 3)
    overlap = overlap_report(m_total, t_comp)

    report = {
        "mode": "quick" if quick else "full",
        "tree": {"leaves": sizes, "total_elems": m_total,
                 "total_mb": m_total * 4 / 2**20, "density": DENSITY,
                 "bucket_bytes": bucket_bytes},
        "per_transport": per_transport,
        "arena_vs_per_leaf": arena_cmp,
        "dispatch_counts": cmp["dispatch_counts"],
        "predicted": predicted,
        "overlap": overlap,
    }
    out_path = os.path.join(os.getcwd(), "BENCH_transport.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}")

    # claims: every sparse transport exercises the full stage decomposition
    for name in TRANSPORTS:
        stages = per_transport[name]["stages"]
        for stage in ("accumulate", "mask", "select", "pack", "transfer",
                      "unpack"):
            assert stage in stages and stages[stage]["total_s"] > 0, \
                f"{name} missing stage {stage}"
    # bucketing actually bucketed (several collectives per step), while
    # fused stayed at one per step
    n_sparse = sum(1 for s in sizes.values()
                   if s * 4 >= DENSE_THRESHOLD_BYTES)
    assert per_transport["bucketed_allgather"]["counts"]["buckets"] \
        > iters, "bucket budget did not split the message set"
    assert per_transport["fused_allgather"]["counts"]["collectives"] == iters
    assert per_transport["per_leaf_allgather"]["counts"]["collectives"] \
        == iters * n_sparse
    # selection dominates pack at p=1 (pack is a concat; select is a scan)
    fused = per_transport["fused_allgather"]["stages"]
    assert fused["select"]["total_s"] > fused["pack"]["total_s"]

    # flat-arena claims (the tier-2 CI gate): select/mask/pack fused
    # dispatches drop from O(leaves) to O(arenas) — strictly fewer — with
    # no more collectives, and the fused stages' wall time is no worse
    for stage in FUSED_STAGES:
        key = f"dispatch_{stage}"
        assert cmp["dispatch_counts"]["arena"][key] \
            < cmp["dispatch_counts"]["per_leaf"][key], \
            f"arena did not reduce {key}"
    assert cmp["collectives"]["arena"] <= cmp["collectives"]["per_leaf"]
    assert cmp["messages"]["arena"] < cmp["messages"]["per_leaf"]
    # wall time: the dispatch asserts above are the deterministic
    # O(arenas) gate; the timing check keeps a noise margin so a loaded
    # CI runner cannot flake it (exact numbers ride in the JSON)
    assert cmp["fused_stage_wall_s"]["arena"] \
        <= 1.2 * cmp["fused_stage_wall_s"]["per_leaf"], \
        "arena mask+select+pack wall time regressed vs per-leaf"
    print("claims: OK (all stages measured on the real pipeline; "
          "bucketed>1 buckets; fused=1 collective/step; arena "
          "mask/select/pack dispatches O(arenas) and no slower)")
    return report


if __name__ == "__main__":
    main()
