"""Measured Fig 10 stage decomposition on the REAL sync pipeline.

Runs ``GradientSync.update`` EAGERLY (op-by-op, no jit) with a
``WallClockTimer`` threaded through the pipeline and the transport, so
every stage of the paper's decomposition — mask (residual accumulation +
state masking), select, pack, transfer, unpack — is timed with a device
barrier, per transport backend. This replaces fig10's artificial
stage loop with the exact code path the trainer runs.

Single-process eager execution means ``sync_axes=()`` (p=1): the
``transfer`` stage measures the backend's buffer plumbing (concat/split,
bucket walk), not wire time — so the Eq 1 predicted decomposition for the
paper's testbeds at real worker counts is emitted alongside
(``cost_model.predicted_shares``), plus the §5.6 comm/compute overlap
headroom against a measured smoke-model backprop. Emits
``BENCH_transport.json`` (uploaded as a CI artifact by the tier-2 job).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.core import WallClockTimer
from repro.core.cost_model import (DENSE_THRESHOLD_BYTES, PIZ_DAINT,
                                   TPU_V5E, eq1_terms, predicted_shares)
from repro.train.trainer import make_gradient_sync

# VGG-flavoured mixed-size tree: a few big sparse leaves (threshold
# bsearch), several mid leaves (trimmed top-k), many small dense leaves —
# all three §5.5 dispatch classes in one step.
FULL_TREE = {
    "fc6": 4_194_304 + 11, "fc7": 2_097_152 + 7, "conv5": 1_048_576 + 3,
    "conv4": 524_288 + 1, "conv3": 262_144, "conv2": 98_304,
    "conv1": 49_152, "bias1": 4_096, "bias2": 1_000, "bias3": 512,
}
QUICK_TREE = {
    "fc6": 1_048_576 + 11, "conv5": 262_144 + 3, "conv4": 98_304,
    "conv2": 49_152, "bias1": 4_096, "bias2": 512,
}

TRANSPORTS = ("fused_allgather", "bucketed_allgather", "per_leaf_allgather",
              "hierarchical")
DENSITY = 0.001
WORKER_COUNTS = (8, 32, 128)


def make_tree(sizes: dict[str, int]):
    rng = np.random.default_rng(0)
    params = {k: jnp.asarray(rng.standard_normal(n), jnp.float32)
              for k, n in sizes.items()}
    grads = {k: jnp.asarray(rng.standard_normal(n) * 0.01, jnp.float32)
             for k, n in sizes.items()}
    return params, grads


def measure_transport(name: str, params, grads, *, iters: int,
                      bucket_bytes: int) -> dict:
    """Per-stage wall time of eager ``GradientSync.update`` steps.

    Built through the trainer's ``make_gradient_sync`` (mesh=None ->
    ``sync_axes=()``) so the measured pipeline is exactly what a
    TrainConfig with this transport would run, timer hook included.
    """
    timer = WallClockTimer()
    tc = TrainConfig(optimizer="rgc", transport=name, density=DENSITY,
                     momentum=0.9, bucket_bytes=bucket_bytes)
    sync = make_gradient_sync(tc, None, timer=timer)
    state = sync.init(params)
    # warmup step (allocator, first-touch) outside the measurement
    _, state = sync.update(grads, state, params, jnp.float32(0.1))
    timer.reset()
    p = params
    for _ in range(iters):
        p, state = sync.update(grads, state, p, jnp.float32(0.1))
    out = timer.summary()
    out["iters"] = iters
    return out


def measure_compute(iters: int = 3) -> float:
    """Eager backprop wall time of a real smoke model (the overlap
    budget of §5.6 — what layer-wise scheduling could hide comm behind)."""
    from repro.configs import get_config
    from repro.models.registry import get_model

    model = get_model(get_config("paper-lstm", smoke=True))
    params = model.init_params(0)
    batch = model.make_train_batch(8, 32)
    grad_fn = jax.value_and_grad(model.loss)
    jax.block_until_ready(grad_fn(params, batch))      # warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(grad_fn(params, batch))
    return (time.perf_counter() - t0) / iters


def overlap_report(m_elems: int, t_compute: float, net=PIZ_DAINT) -> dict:
    """§5.6 headroom: which share of the Eq 1 bandwidth term layer-wise
    overlap could hide behind a backprop of the measured length."""
    per_p = {}
    for p in WORKER_COUNTS:
        terms = eq1_terms(p, m_elems, DENSITY, net)
        bw = terms["bandwidth"]
        hidden = min(0.9 * t_compute, bw)
        per_p[str(p)] = {
            "bandwidth_s": bw,
            "hidden_s": hidden,
            "hidden_share": hidden / bw if bw > 0 else 1.0,
            "exposed_s": bw - hidden,
        }
    return {"t_compute_s": t_compute, "net": net.name, "per_p": per_p}


def main(quick: bool = False) -> dict:
    sizes = QUICK_TREE if quick else FULL_TREE
    iters = 2 if quick else 5
    # budget sized against the PACKED messages (density * 0.1% of the
    # tree), not the raw leaves — small enough that the message set
    # splits into several buckets per step
    bucket_bytes = 8_192 if quick else 32_768
    params, grads = make_tree(sizes)
    m_total = sum(sizes.values())
    print(f"bench_transport: {len(sizes)} leaves, "
          f"{m_total * 4 / 2**20:.1f} MB, density {DENSITY}, "
          f"{iters} eager iterations per transport")

    per_transport = {}
    print("transport,stage,mean_ms,share,calls")
    for name in TRANSPORTS:
        summ = measure_transport(name, params, grads, iters=iters,
                                 bucket_bytes=bucket_bytes)
        per_transport[name] = summ
        for stage, s in summ["stages"].items():
            print(f"{name},{stage},{s['mean_ms']:.3f},{s['share']:.3f},"
                  f"{s['calls']}")

    predicted = {}
    for net in (PIZ_DAINT, TPU_V5E):
        predicted[net.name] = {
            str(p): predicted_shares(p, m_total, DENSITY, net)
            for p in WORKER_COUNTS}

    t_comp = measure_compute(iters=1 if quick else 3)
    overlap = overlap_report(m_total, t_comp)

    report = {
        "mode": "quick" if quick else "full",
        "tree": {"leaves": sizes, "total_elems": m_total,
                 "total_mb": m_total * 4 / 2**20, "density": DENSITY,
                 "bucket_bytes": bucket_bytes},
        "per_transport": per_transport,
        "predicted": predicted,
        "overlap": overlap,
    }
    out_path = os.path.join(os.getcwd(), "BENCH_transport.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}")

    # claims: every sparse transport exercises the full stage decomposition
    for name in TRANSPORTS:
        stages = per_transport[name]["stages"]
        for stage in ("mask", "select", "pack", "transfer", "unpack"):
            assert stage in stages and stages[stage]["total_s"] > 0, \
                f"{name} missing stage {stage}"
    # bucketing actually bucketed (several collectives per step), while
    # fused stayed at one per step
    n_sparse = sum(1 for s in sizes.values()
                   if s * 4 >= DENSE_THRESHOLD_BYTES)
    assert per_transport["bucketed_allgather"]["counts"]["buckets"] \
        > iters, "bucket budget did not split the message set"
    assert per_transport["fused_allgather"]["counts"]["collectives"] == iters
    assert per_transport["per_leaf_allgather"]["counts"]["collectives"] \
        == iters * n_sparse
    # selection dominates pack at p=1 (pack is a concat; select is a scan)
    fused = per_transport["fused_allgather"]["stages"]
    assert fused["select"]["total_s"] > fused["pack"]["total_s"]
    print("claims: OK (all stages measured on the real pipeline; "
          "bucketed>1 buckets; fused=1 collective/step)")
    return report


if __name__ == "__main__":
    main()
