"""Table 1 / Fig 6 reproduction: convergence parity of SGD vs RGC variants
on the SIMULATED CLUSTER (tests/harness): 8 forced host devices on a
("data",) mesh, every worker compressing its OWN local gradient — the
claim is validated end-to-end as a real multi-worker run, not per-kernel.

The paper trains CNNs/LSTMs to equal accuracy under 0.1% RGC. At this
container's scale we use the paper's OWN evaluation model (the 2x1500
LSTM, reduced) plus a reduced transformer, trained on a synthetic bigram
language whose conditional entropy is a known achievable floor — the
convergence-parity claim becomes: every optimizer variant approaches the
dense baseline's loss, within tolerance, on the same budget. The
DGC-corrected pipeline ("momentum+clip(threshold_bsearch)" with dense
warm-up, §5.7) is the row the tier-2 tests gate on.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "tests"))

from harness import run_cluster  # noqa: E402 (path setup above)

from repro.configs import get_config  # noqa: E402
from repro.data.synthetic import bigram_entropy, bigram_transition  # noqa: E402

DEVICES = 8

# optimizer rows: name -> extra run_cluster spec. Every sparse row uses
# the §5.7 dense warm-up (the paper's own recommendation at scale; the
# DGC density ramp's high-sparsity stages dominate short budgets — see
# tests/test_convergence.py::test_dgc_density_ramp_learns).
VARIANTS = {
    "sgd": dict(optimizer="dense", transport="dense_psum"),
    "rgc": dict(optimizer="rgc", dense_warmup=True),
    "rgc_quant": dict(optimizer="rgc_quant", dense_warmup=True),
    "rgc_dgc": dict(optimizer="momentum+clip(threshold_bsearch)",
                    dense_warmup=True),
}


def train_one(arch: str, variant: str, steps: int, *, lr=0.1,
              density=0.01, seed=0) -> float:
    spec = dict(arch=arch, steps=steps, lr=lr, momentum=0.9,
                local_clip=1.0, density=density, seed=seed,
                warmup_steps_per_stage=max(1, steps // 8),
                **VARIANTS[variant])
    return run_cluster(spec, devices=DEVICES)["held_loss"]


def main(quick: bool = False):
    steps = 60 if quick else 200
    rows = []
    print(f"tab1_convergence: held-out loss after equal budget "
          f"({DEVICES}-way simulated cluster)")
    print("model," + ",".join(VARIANTS) + ",entropy_floor")
    for arch in ("paper-lstm", "internlm2-1.8b"):
        cfg = get_config(arch, smoke=True)
        floor = bigram_entropy(bigram_transition(cfg.vocab_size, seed=0))
        losses = {v: train_one(arch, v, steps) for v in VARIANTS}
        print(f"{arch}," + ",".join(f"{losses[v]:.4f}" for v in VARIANTS)
              + f",{floor:.4f}")
        rows.append((arch, losses))
        # parity claim: every sparse variant keeps a meaningful fraction of
        # the dense progress from init (~6.24) even at the --quick budget
        # (where only the post-warm-up tail is sparse); the DGC-corrected
        # row is held to the tighter 5% bar, at the full 200-step budget,
        # by tests/test_convergence.py
        init = 6.24
        for v in VARIANTS:
            if v == "sgd":
                continue
            assert (init - losses[v]) > 0.4 * (init - losses["sgd"]), \
                f"{arch}: {v} lagging ({losses[v]:.4f} vs {losses['sgd']:.4f})"
    print("claims: OK (RGC variants converge comparably to SGD)")
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
