"""Table 1 / Fig 6 reproduction: convergence parity of SGD vs RGC vs
quantized RGC.

The paper trains CNNs/LSTMs to equal accuracy under 0.1% RGC. At this
container's scale we use the paper's OWN evaluation model (the 2x1500
LSTM, reduced) plus a reduced transformer, trained on a synthetic bigram
language whose conditional entropy is a known achievable floor — the
convergence-parity claim becomes: all three optimizers approach the same
loss, within tolerance, on the same budget.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.data import bigram_batches
from repro.data.synthetic import bigram_entropy, bigram_transition
from repro.train.trainer import Trainer


def train_one(arch: str, optimizer: str, steps: int, *, lr=0.5,
              density=0.01, seed=0):
    cfg = get_config(arch, smoke=True)
    tc = TrainConfig(lr=lr, momentum=0.0, optimizer=optimizer,
                     density=density, local_clip=1.0, seed=seed)
    tr = Trainer(cfg, tc)
    state = tr.init_state()
    batches = bigram_batches(cfg.vocab_size, 8, 64, seed=seed)
    state = tr.run(state, batches, steps, log_every=0)
    # held-out loss on fresh batches from the same chain
    src = bigram_batches(cfg.vocab_size, 8, 64, seed=seed)
    for _ in range(steps + 3):
        held = next(src)
    return float(tr.model.loss(state.params, {
        k: jnp.asarray(v) for k, v in held.items()}))


def main(quick: bool = False):
    steps = 60 if quick else 200
    rows = []
    print("tab1_convergence: held-out loss after equal budget")
    print("model,sgd,rgc,rgc_quant,entropy_floor")
    for arch in ("paper-lstm", "internlm2-1.8b"):
        cfg = get_config(arch, smoke=True)
        floor = bigram_entropy(bigram_transition(cfg.vocab_size, seed=0))
        sgd = train_one(arch, "dense", steps)
        rgc = train_one(arch, "rgc", steps)
        quant = train_one(arch, "rgc_quant", steps)
        print(f"{arch},{sgd:.4f},{rgc:.4f},{quant:.4f},{floor:.4f}")
        rows.append((arch, sgd, rgc, quant))
        # parity claim: RGC within 10% of SGD's progress from init (~6.24)
        init = 6.24
        assert (init - rgc) > 0.5 * (init - sgd), f"{arch}: RGC lagging"
    print("claims: OK (RGC/quant converge comparably to SGD)")
    return rows


if __name__ == "__main__":
    main()
