"""End-to-end driver (deliverable b): train a ~100M-param LM with RGC on a
multi-device mesh for a few hundred steps, with warm-up density schedule,
checkpointing, and held-out evaluation.

    REPRO_HOST_DEVICES=8 PYTHONPATH=src python examples/train_lm_rgc.py \
        [--steps 300] [--full-size]

Default trains a ~100M-parameter internlm2-family config (12 layers,
d_model 768) on 8 forced host devices as a (4 data x 2 model) mesh — the
same nested-shard_map RGC code path the production pod uses.
"""
import os

if "XLA_FLAGS" not in os.environ:
    n = os.environ.get("REPRO_HOST_DEVICES", "8")
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.data import bigram_batches
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer


def build_config(full_size: bool):
    base = get_config("internlm2-1.8b")
    if full_size:
        return base
    # ~100M-parameter variant of the same family
    return dataclasses.replace(
        base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=8192, dtype=jnp.float32,
        attn_q_chunk=128, attn_kv_chunk=128, loss_chunk=256)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--density", type=float, default=0.001)
    ap.add_argument("--optimizer", default="rgc",
                    help="rgc | rgc_quant | dense | any registered "
                    "compressor spec (repro.core.registry)")
    ap.add_argument("--transport", default="fused_allgather",
                    choices=["fused_allgather", "per_leaf_allgather",
                             "dense_psum"])
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = build_config(args.full_size)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(max(n_dev // 2, 1), 2) if n_dev >= 2 else None
    tc = TrainConfig(lr=0.1, momentum=0.9, optimizer=args.optimizer,
                     transport=args.transport,
                     density=args.density, warmup_steps_per_stage=20,
                     dense_warmup=True, local_clip=1.0)
    trainer = Trainer(cfg, tc, mesh=mesh, ckpt_dir=args.ckpt_dir)
    state = trainer.init_state()
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"params: {n/1e6:.1f}M  devices: {n_dev}  "
          f"mesh: {mesh.devices.shape if mesh else None}")
    print(f"warm-up: dense allreduce for {20 * 4} steps, then "
          f"D={args.density:.3%} RGC (§5.7 RedSync schedule)")

    t0 = time.time()
    state = trainer.run(
        state, bigram_batches(cfg.vocab_size, args.batch, args.seq, seed=0),
        num_steps=args.steps, log_every=20)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s on CPU host)")
    print(f"checkpoint written under {args.ckpt_dir}")


if __name__ == "__main__":
    main()
