"""Batched serving example: prefill a prompt batch, then decode tokens
with per-family caches (KV ring buffers / recurrent states).

    PYTHONPATH=src python examples/serve_decode.py [--arch rwkv6-3b]

Runs the reduced config of the chosen architecture; demonstrates that the
same ServeLoop drives dense (KV cache), SSM (constant state), hybrid
(mixed), and enc-dec (cross-attention cache) families.
"""
import argparse
import time

import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import get_model
from repro.train.serve import ServeLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = get_model(cfg)
    if model.cache_struct is None:
        raise SystemExit(f"{args.arch} has no decode path")
    params = model.init_params(0)

    loop = ServeLoop(model, batch=args.batch,
                     max_len=args.prompt_len + args.gen_tokens)
    prompts = model.make_train_batch(args.batch, args.prompt_len)

    t0 = time.time()
    toks = loop.generate(params, prompts, args.gen_tokens)
    dt = time.time() - t0
    print(f"arch={cfg.name} family={cfg.family}")
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen_tokens / dt:.1f} tok/s, CPU, "
          f"untrained weights)")
    print("sample token ids:", toks[0, :12].tolist())


if __name__ == "__main__":
    main()
