"""Quickstart: train a small LM with Residual Gradient Compression.

    PYTHONPATH=src python examples/quickstart.py

Single process, CPU-friendly. Shows the optimizer modes side by side on
the same model + data budget: dense baseline, RGC (0.1%-style sparse
sync, here 1% for the tiny model), quantized RGC, a registry-named
compressor ("threshold_bsearch" forces Alg 3 on every leaf — any name
from repro.core.registry works, e.g. "quantized(trimmed_topk)"), and the
DGC-corrected pipeline ("momentum+clip(threshold_bsearch)": momentum
correction + local clipping ahead of the selector — see
repro.core.correction for the spec grammar).
"""
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.data import bigram_batches
from repro.train.trainer import Trainer


def main() -> None:
    cfg = get_config("internlm2-1.8b", smoke=True)
    print(f"model: {cfg.name} (reduced: {cfg.num_layers}L d={cfg.d_model})")

    for optimizer in ("dense", "rgc", "rgc_quant", "threshold_bsearch",
                      "momentum+clip(threshold_bsearch)"):
        # the "momentum" correction takes its coefficient from tc.momentum
        corrected = "momentum" in optimizer
        tc = TrainConfig(lr=0.1 if corrected else 0.3,
                         momentum=0.9 if corrected else 0.0,
                         optimizer=optimizer, density=0.01, local_clip=1.0)
        trainer = Trainer(cfg, tc)
        state = trainer.init_state()
        print(f"\n--- optimizer = {optimizer} ---")
        trainer.run(state,
                    bigram_batches(cfg.vocab_size, 8, 64, seed=0),
                    num_steps=30, log_every=10)


if __name__ == "__main__":
    main()
