"""qwen3-32b [dense] — hf:Qwen/Qwen3-32B (assignment card cites Qwen3-8B;
dims below are the assigned 32b row).

64L, d_model 5120, 64 heads (GQA kv=8, head_dim 128), d_ff 25600,
vocab 151936. QK-norm (per-head RMSNorm on q and k), RoPE 1e6, untied
embeddings, full attention -> long_500k skipped.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25_600,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, dtype=jnp.float32,
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
