"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin / RecurrentGemma).

38L, d_model 4096, 16 heads MQA (kv=1, head_dim 256), d_ff 12288,
vocab 256000. Temporal mix pattern 1 local-attention : 2 RG-LRU
(superblocks R,R,L), sliding window 2048, lru_width = d_model, causal
depthwise conv1d width 4. Gemma-style embed scale, tied embeddings.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    window_size=2048,
    layer_pattern=("R", "R", "L"),
    lru_width=4096,
    conv1d_width=4,
    tie_embeddings=True,
    embed_scale=True,
    act="gelu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=5, d_model=128, num_heads=4, num_kv_heads=1,
        head_dim=32, d_ff=256, vocab_size=512, window_size=16,
        lru_width=128, dtype=jnp.float32,
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
