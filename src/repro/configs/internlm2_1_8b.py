"""internlm2-1.8b [dense] — arXiv:2403.17297.

24L, d_model 2048, 16 heads (GQA kv=8, head_dim 128), d_ff 8192,
vocab 92544. Llama-style: RoPE 1e6, SiLU gated MLP, untied embeddings,
full attention (no window) -> long_500k decode is skipped (DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_544,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, dtype=jnp.float32,
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
