"""Config system: model / parallelism / RGC / run configs.

Every assigned architecture gets one file in this package defining an exact
``ModelConfig`` (source cited in its docstring) plus a ``smoke()`` reduced
variant (2 layers, d_model <= 512, <= 4 experts) for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | rwkv6 | hybrid | vlm | encdec | lstm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None   # gemma3 dual-theta
    window_size: Optional[int] = None           # sliding-window attention
    layer_pattern: Optional[tuple[str, ...]] = None  # cycled codes, e.g. ("L",)*5+("G",)
    attn_logit_softcap: Optional[float] = None
    tie_embeddings: bool = True
    embed_scale: bool = False                   # gemma-style sqrt(d) input scale

    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # dispatch implementation: "onehot" (GShard-style one-hot matmuls,
    # MXU-friendly, O(T*E*C) work — the baseline) or "scatter"
    # (scatter/gather packing, O(T*k*D) — the §Perf long-sequence win)
    moe_impl: str = "onehot"

    # recurrent / hybrid
    lru_width: Optional[int] = None
    conv1d_width: int = 4
    rwkv_head_dim: int = 64
    lora_dim: int = 32                          # rwkv6 ddlerp low-rank dim

    # modality stubs
    num_prefix_tokens: int = 0                  # vlm patch embeds
    encoder_layers: int = 0                     # whisper
    encoder_frames: int = 0
    max_target_positions: int = 0               # learned positions (whisper)

    # numerics / structure
    act: str = "silu"
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    scan_layers: bool = True
    remat: bool = True

    # chunk sizes (memory-bounded attention / loss)
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    loss_chunk: int = 2048
    wkv_chunk: int = 64

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def pattern_codes(self) -> tuple[int, ...]:
        """Per-layer code: 0 = global/full attn, 1 = local/SWA, 2 = recurrent."""
        if self.layer_pattern is None:
            return tuple(1 if self.window_size else 0
                         for _ in range(self.num_layers))
        table = {"G": 0, "L": 1, "R": 2}
        pat = [table[c] for c in self.layer_pattern]
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))


@dataclass(frozen=True)
class ParallelConfig:
    """Logical-axis -> mesh-axis rules. Axes that don't divide are dropped
    to replication at spec-resolution time."""
    rules: tuple[tuple[str, Optional[str]], ...] = (
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("ffn", "model"),
        ("expert", None),        # TP-within-expert default; EP via override
        ("expert_ffn", "model"),
        ("lru", "model"),
        ("embed", None),
        ("layers", None),
    )
    batch_axes: tuple[str, ...] = ("data",)     # +"pod" on the 3-D mesh

    def rule(self, logical: str) -> Optional[str]:
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def with_rule(self, logical: str, mesh_axis: Optional[str]) -> "ParallelConfig":
        rules = tuple((k, mesh_axis if k == logical else v)
                      for k, v in self.rules)
        if logical not in [k for k, _ in self.rules]:
            rules = rules + ((logical, mesh_axis),)
        return dataclasses.replace(self, rules=rules)


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 0.1
    momentum: float = 0.9
    nesterov: bool = False
    weight_decay: float = 0.0
    # rgc | rgc_quant | dense | any registered compressor spec
    # (repro.core.registry), e.g. "threshold_bsearch" or
    # "quantized(trimmed_topk)" — fixed per-leaf dispatch through it.
    # The spec may prefix '+'-joined DGC correction names
    # (repro.core.correction: momentum, factor_masking/masking,
    # local_clip/clip, warmup) ahead of the base, e.g.
    # "momentum+clip(threshold_bsearch)" or "warmup(rgc)"; corrections-only
    # specs default the base to "rgc". Spec corrections are ADDITIVE: the
    # momentum/local_clip fields below stay the on/off switches for their
    # corrections whether or not the spec names them (ablate by zeroing
    # the field), so "warmup(rgc)" == "rgc" + the density ramp.
    # ("dense_fsdp" is handled only by launch/dryrun's
    # make_fsdp_dense_step branch, not by the GradientSync builder.)
    optimizer: str = "rgc"
    # sparse collective backend: fused_allgather | bucketed_allgather |
    # hierarchical | per_leaf_allgather | dense_psum (dense-only baseline)
    transport: str = "fused_allgather"
    # bucketed_allgather: byte budget per fused collective bucket (messages
    # are greedily packed into contiguous buckets of at most this size;
    # an oversized leaf gets its own bucket)
    bucket_bytes: int = 4 * 1024 * 1024
    # hierarchical transport: mesh axis treated as the intra-node (fast,
    # dense-psum) hop; None = the LAST sync axis — "local" on the
    # harness's ("node","local") mesh, "data" on the multi-pod
    # ("pod","data") batch axes. Every other sync axis forms the
    # inter-node sparse-allgather hop.
    intra_axis: Optional[str] = None
    # §5.6 overlap scheduler (repro.core.overlap): "sequential" (one
    # full-tree transport barrier per step — the historical order),
    # "chunked" (partition the tree into reverse-parameter-order chunks
    # under bucket_bytes and dispatch each chunk's collective as soon as
    # its select/mask/pack is issued — bitwise identical results, >= 2
    # transport dispatches per step), or "stale1" (communicate step t-1's
    # compressed residual during step t — double-buffered, one step of
    # sparse staleness; requires a fixed target density, dense warm-up ok)
    schedule: str = "sequential"
    density: float = 0.001
    warmup_steps_per_stage: int = 0
    dense_warmup: bool = False
    local_clip: float | None = None
    seed: int = 0
    residual_dtype: str = "f32"     # f32 | bf16 (large-model memory lever)
    # Flat residual arenas (repro.core.arena): coalesce same-dtype sparse
    # leaves into contiguous f32 arenas so the accumulate/select/mask/pack
    # stages each run once per ARENA instead of once per leaf — O(arenas)
    # fused kernel dispatches with bitwise-identical params/state.
    # Selection stays segmented (each leaf keeps its own k). Disable to
    # get the historical per-leaf pipeline (benchmark baseline).
    fuse_leaves: bool = True
    # Also fuse residual accumulation into one single-launch arena pass
    # (residual-update + block-stats kernel). Off by default: XLA may
    # FMA-contract the momentum product differently than the per-leaf
    # graph (<= 1 ulp drift; exact when momentum == weight_decay == 0),
    # so the default keeps accumulation on the bitwise per-leaf graph.
    fuse_accumulate: bool = False
    # Selection-kernel backend for trimmed_topk / threshold_bsearch:
    # "jnp" (pure-XLA selectors) or "pallas" (the TPU kernels;
    # auto-compiled on TPU, interpreted elsewhere).
    backend: str = "jnp"
