"""The paper's own evaluation model (RedSync §6.2): 2-layer LSTM LM with
1500 hidden units per layer (Press & Wolf 2016), untied embeddings,
vanilla SGD + gradient clipping, PTB (vocab 10k) / WikiText-2 (vocab 33k).

Used by the Table 1 / Table 2 / Fig 6 convergence benchmarks and the LSTM
rows of Fig 7/9 — NOT part of the 10-arch x 4-shape dry-run matrix.

model size: embed 10000x1500 + lstm 2x(4x1500x(1500+1500)) + head
1500x10000 — dominated by embed/softmax, the paper's high
communication-to-computation regime.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="paper-lstm",
    family="lstm",
    num_layers=2,
    d_model=1500,            # embedding size
    num_heads=1,
    num_kv_heads=1,
    head_dim=1500,
    d_ff=1500,               # hidden units
    vocab_size=10_000,       # PTB
    tie_embeddings=False,
    dtype=jnp.float32,       # paper trains fp32
)

WIKI2 = dataclasses.replace(FULL, name="paper-lstm-wiki2", vocab_size=33_278)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, d_model=64, d_ff=96, head_dim=96, vocab_size=512,
        loss_chunk=32)
