"""paligemma-3b [vlm] — arXiv:2407.07726 (PaliGemma).

Language backbone: 18L, d_model 2048, 8 heads (GQA kv=1 — MQA,
head_dim 256), d_ff 16384, vocab 257216. Gemma-style tied embeddings +
embed scale. The SigLIP vision tower + projector are the sanctioned STUB:
``input_specs`` provides 256 projected patch embeddings [B, 256, d_model];
they form a bidirectional prefix (prefix-LM mask) ahead of the causal text.

Full-attention prefix-LM -> long_500k skipped (DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    num_prefix_tokens=256,
    tie_embeddings=True,
    embed_scale=True,
    act="gelu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=4, num_kv_heads=1,
        head_dim=32, d_ff=256, vocab_size=512, num_prefix_tokens=8,
        dtype=jnp.float32, attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
