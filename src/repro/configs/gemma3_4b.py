"""gemma3-4b [dense] — hf:google/gemma-3-4b-pt family (assignment card
cites google/gemma-3-1b-pt; dims below are the assigned 4b row).

34L, d_model 2560, 8 heads (GQA kv=4, head_dim 256), d_ff 10240,
vocab 262144. 5 local(SWA 1024) : 1 global layer pattern, 128k context;
dual RoPE theta (10k local / 1M global); tied embeddings, gemma-style
sqrt(d) embed scale and attn logit softcapping is absent in gemma3 (dropped
vs gemma2) so softcap=None.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    window_size=1024,
    layer_pattern=("L", "L", "L", "L", "L", "G"),
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
    embed_scale=True,
    act="gelu",
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, window_size=16,
        layer_pattern=("L", "G"), dtype=jnp.float32,
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
