"""Config registry: ``--arch <id>`` lookup for the 10 assigned architectures
(+ the paper's own LSTM)."""
from __future__ import annotations

from . import (gemma3_4b, granite_moe_3b_a800m, grok_1_314b, h2o_danube_3_4b,
               internlm2_1_8b, paligemma_3b, paper_lstm, qwen3_32b,
               recurrentgemma_9b, rwkv6_3b, whisper_large_v3)
from .base import ModelConfig, ParallelConfig, TrainConfig
from .shapes import SHAPES, InputShape

_MODULES = {
    "gemma3-4b": gemma3_4b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "internlm2-1.8b": internlm2_1_8b,
    "rwkv6-3b": rwkv6_3b,
    "grok-1-314b": grok_1_314b,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "qwen3-32b": qwen3_32b,
    "paligemma-3b": paligemma_3b,
    "h2o-danube-3-4b": h2o_danube_3_4b,
    "whisper-large-v3": whisper_large_v3,
    "paper-lstm": paper_lstm,
}

ARCH_IDS = tuple(k for k in _MODULES if k != "paper-lstm")   # the 10-arch pool


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = _MODULES[arch]
    return mod.smoke() if smoke else mod.FULL


__all__ = ["ModelConfig", "ParallelConfig", "TrainConfig", "InputShape",
           "SHAPES", "ARCH_IDS", "get_config"]
