"""whisper-large-v3 [audio] — arXiv:2212.04356 (+ large-v3 model card).

Encoder-decoder transformer backbone: 32 encoder + 32 decoder layers,
d_model 1280, 20 heads (MHA, kv=20, head_dim 64), d_ff 5120, vocab 51866.
The mel-spectrogram + 2xConv1d frontend is the sanctioned STUB:
``input_specs`` provides 1500 precomputed frame embeddings (30 s of audio
at 2x conv stride). GELU MLP with biases, pre-LN LayerNorm.

Enc-dec: decode shapes run with the stub encoder embeddings in the batch
(decoder self-KV + cross-KV caches); long_500k skipped (30 s fixed source,
DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    encoder_layers=32,
    encoder_frames=1500,
    act="gelu",
    tie_embeddings=True,    # whisper ties token embedding and output proj
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=512, encoder_layers=2,
        encoder_frames=12, dtype=jnp.float32,
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
