"""rwkv6-3b [ssm] — arXiv:2404.05892 (Eagle & Finch; "Finch" = RWKV6).

32L, d_model 2560 (attention-free; 40 wkv heads of dim 64), channel-mix
d_ff 8960, vocab 65536. Data-dependent decay + ddlerp token shift
(low-rank dim 32). Sub-quadratic by construction -> long_500k runs.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="rwkv6-3b",
    family="rwkv6",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # d_model / rwkv_head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    rwkv_head_dim=64,
    lora_dim=32,
    tie_embeddings=False,
    wkv_chunk=64,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=8, num_kv_heads=8,
        head_dim=16, d_ff=256, vocab_size=512, rwkv_head_dim=16,
        lora_dim=8, dtype=jnp.float32, wkv_chunk=8, loss_chunk=32)
