"""grok-1-314b [moe] — hf:xai-org/grok-1.

64L, d_model 6144, 48 heads (GQA kv=8, head_dim 128), expert d_ff 32768,
vocab 131072; MoE with 8 experts, top-2 routing. Attention logit softcap 30
(grok-1 model card), untied embeddings. Full attention -> long_500k skipped.

The single biggest model in the pool (314B total / ~86B active): the
dry-run must shard experts' FFN over the model axis (TP-within-expert,
d_ff 32768 / 16 = 2048 per device) to fit.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32_768,
    vocab_size=131_072,
    num_experts=8,
    num_experts_per_tok=2,
    moe_impl="scatter",   # §Perf default; onehot = GShard baseline via --set
    attn_logit_softcap=30.0,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=512, num_experts=4,
        num_experts_per_tok=2, dtype=jnp.float32,
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
