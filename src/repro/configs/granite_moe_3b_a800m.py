"""granite-moe-3b-a800m [moe] — hf:ibm-granite/granite-3.0-3b-a800m-base
(assignment card cites the 1b-a400m sibling; dims below are the assigned row).

32L, d_model 1536, 24 heads (GQA kv=8, head_dim 64), expert d_ff 512,
vocab 49155; MoE with 40 experts, top-8 routing. Tied embeddings.

Fine-grained MoE regime: many small experts (d_ff 512 < 16-way model axis
granularity), so expert FFN weights replicate on the model axis and the
interesting §Perf question is expert-parallel dispatch (all-to-all) instead.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    num_experts=40,
    num_experts_per_tok=8,
    moe_impl="scatter",   # §Perf default; onehot = GShard baseline via --set
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=512, num_experts=4,
        num_experts_per_tok=2, dtype=jnp.float32,
        attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
