"""h2o-danube-3-4b [dense] — arXiv:2401.16818 (H2O-Danube series;
llama + mistral architecture mix).

24L, d_model 3840, 32 heads (GQA kv=8, head_dim 120), d_ff 10240,
vocab 32000. Mistral-style sliding-window attention (window 4096) on all
layers per the assignment card -> sub-quadratic SWA decode, long_500k RUNS
with a 4096 ring-buffer KV.

head_dim 120 is not 128-aligned (3840/32) — noted in the roofline analysis
as an MXU padding inefficiency inherited from the model card.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10_240,
    vocab_size=32_000,
    window_size=4096,
    tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, window_size=16,
        dtype=jnp.float32, attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32)
