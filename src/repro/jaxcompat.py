"""Version compatibility shims for the jax API surface this repo uses.

The codebase targets the modern ``jax.shard_map`` API (keyword
``axis_names`` for partial-manual regions, ``check_vma``). Older jax
(< ~0.6, e.g. the 0.4.x CPU wheels in CI containers) only ships
``jax.experimental.shard_map.shard_map`` with the complementary ``auto``
set and ``check_rep``. This wrapper maps between the two so the trainer's
nested partial-manual pattern runs on both.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax


def axis_size(name):
    """``jax.lax.axis_size``; on older jax the psum-of-1 idiom (which jax
    constant-folds to the bound axis size)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh=None, axis_names=None, in_specs, out_specs,
              check_vma: bool = False, fallback_mesh=None):
    """``jax.shard_map`` with old-jax fallback.

    ``axis_names`` — the MANUAL axes (modern semantics); None = all mesh
    axes. ``fallback_mesh`` is only consulted on the legacy path, which
    requires an explicit mesh even where modern jax infers it from the
    surrounding context (e.g. an inner shard_map nested in a manual
    region).
    """
    if hasattr(jax, "shard_map"):
        kw: dict[str, Any] = {}
        if mesh is not None:
            kw["mesh"] = mesh
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        sig = inspect.signature(jax.shard_map).parameters
        if "check_vma" in sig:
            kw["check_vma"] = check_vma
        elif "check_rep" in sig:
            kw["check_rep"] = check_vma
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             **kw)

    from jax.experimental.shard_map import shard_map as _legacy
    m = mesh if mesh is not None else fallback_mesh
    if m is None:
        raise ValueError(
            "legacy jax.experimental.shard_map needs an explicit mesh: "
            "pass mesh= or fallback_mesh=")
    manual = (set(m.axis_names) if axis_names is None else set(axis_names))
    auto = frozenset(set(m.axis_names) - manual)
    return _legacy(f, mesh=m, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma, auto=auto)
