"""Overlapped backprop/communication scheduling (RedSync §5.6).

The paper attributes much of its end-to-end win to hiding communication
behind backprop, and DGC / Agarwal et al. (2103.00543) show that without
REAL overlap, compression's bandwidth savings fail to become wall-clock
savings. Until now this repo only *modeled* that overlap
(``overlap_report`` in ``benchmarks/bench_transport.py``); every
transport ran strictly after the full gradient tree was materialized —
one end-of-step barrier.

This module makes the dispatch order a pluggable ``Schedule``
(``repro.core.api``), registry-addressable via ``TrainConfig.schedule``:

``sequential``
    The historical order: compress every unit, ONE transport barrier,
    then unpack/apply. The reference everything else is differenced
    against.

``chunked``
    The §5.6 pipelined order. ``partition_chunks`` splits the gradient
    tree into ordered chunks in REVERSE parameter order — last-layer
    gradients, first out of backprop, sync first — under the
    ``bucket_bytes`` byte budget, never splitting a leaf. Each chunk's
    accumulate/select/mask/pack runs and its transport collective is
    DISPATCHED immediately, before the next chunk's compute is issued;
    unpack/apply drains afterwards. Under jit this hands XLA's
    latency-hiding scheduler one independent collective per chunk to
    overlap with the remaining chunks' select/pack compute (instead of
    one full-tree barrier it cannot move); eagerly, jax's non-blocking
    dispatch overlaps them for real. Every per-unit computation is the
    same graph as ``sequential`` (the PR-4 pinned numerics make the
    accumulate/select math graph-shape independent), collectives carry
    the same bytes, and updates to distinct leaves commute — so params
    and optimizer state are BITWISE identical to ``sequential``
    (tests/test_overlap.py, tests/_overlap_prog.py), only the number
    and order of transport dispatches change.

``stale1``
    One-step-delayed, double-buffered sync: step *t* COMMUNICATES the
    messages step *t-1* packed, so on a real wire the collective for
    step *t-1* overlaps the whole of step *t*'s forward+backward — the
    maximal §5.6 overlap, bought with one step of staleness on the
    sparse updates. Residual correctness: a selected value is removed
    from the residual when packed and applied exactly once, one step
    later, from the pending buffer — no update is ever dropped or
    double-applied; only the last step's buffer is left in flight when
    training stops. Dense (small) leaves stay synchronous, and a §5.7
    dense warm-up step (density >= 1.0 sentinel) runs fully synchronous
    while carrying the pending buffer through UNTOUCHED (zero-count
    when warm-up precedes the first sparse step; still holding a prior
    sparse step's values if a dense step is interleaved mid-training —
    applied at the next sparse step, never dropped), so the staleness
    only ever touches the sparse path. Requires a FIXED target density (the pending buffers
    are trace-time shapes): the dense warm-up is supported, the DGC
    intermediate-density ramp is rejected loudly. Convergence cost is
    measured on the tier-2 harness (tests/test_convergence.py).

Chunk layout invariants (property-tested in tests/test_overlap.py):
chunks cover every leaf exactly once; concatenating the chunks' leaf
lists walks the tree in exact reverse parameter order; each chunk's
byte total respects the budget unless a single oversized leaf forms a
singleton chunk; a leaf's segment is never split across chunks.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax

from . import registry
from .transport import assign_buckets


class Chunk(NamedTuple):
    """One pipeline chunk: a contiguous run of the REVERSED leaf order."""

    cid: int
    leaves: tuple[int, ...]    # leaf indices, reverse parameter order
    nbytes: int                # summed gradient bytes of the chunk


def partition_chunks(nbytes: Sequence[int],
                     chunk_bytes: int) -> tuple[Chunk, ...]:
    """Greedy reverse-order partition of per-leaf gradient byte sizes.

    Walks the leaves LAST-first (reverse parameter order — the order
    backprop produces gradients) and closes the current chunk whenever
    the next leaf would push it past ``chunk_bytes``; a leaf larger than
    the budget on its own still gets a (singleton) chunk — nothing is
    ever dropped or split. The greedy budget rule IS
    ``transport.assign_buckets`` (one definition of the invariant),
    applied to the reversed leaf order. The byte sizes are the RAW
    gradient bytes (``size * dtype.itemsize``), not packed-message
    bytes: chunk formation models when a chunk's gradients exist
    relative to backprop, before compression has happened.
    """
    order = list(reversed(range(len(nbytes))))
    buckets = assign_buckets([int(nbytes[i]) for i in order], chunk_bytes)
    return tuple(
        Chunk(cid, tuple(order[j] for j in bucket),
              sum(int(nbytes[order[j]]) for j in bucket))
        for cid, bucket in enumerate(buckets))


class ScheduleState(NamedTuple):
    """Optimizer state of a double-buffered schedule (``stale1``).

    ``leaf`` is the ordinary params-congruent LeafState tree;
    ``pending`` holds the packed wire messages of the PREVIOUS step
    (zero-count buffers at init), in the static unit order of the
    target-density plan."""

    leaf: Any
    pending: tuple[jax.Array, ...]


class SequentialSchedule:
    """Full-tree barrier order: compress all -> one transfer -> apply."""

    name = "sequential"

    def init_state(self, sync, params, leaf_state):
        return leaf_state

    def wrap_state_specs(self, leaf_specs, replicated):
        """Partition specs for the full schedule state, given the
        LeafState tree's specs (no extra state here)."""
        return leaf_specs

    def step(self, sync, grads, state, params, lr, density):
        all_dense = density >= 1.0
        (treedef, leaves_raw, leaves_g, leaves_p, leaves_s,
         n_workers) = sync._context(grads, state, params)
        # plan from the RAW leaves (§5.5 dispatch on true storage dtype)
        plan = sync._plan(grads, treedef, leaves_raw, density, all_dense)
        new_states = list(leaves_s)
        new_params = list(leaves_p)

        messages, meta = sync._compress_plan(
            plan, leaves_g, leaves_p, leaves_s, new_states)
        gathered = sync._gather(messages)
        sync._apply_gathered(gathered, meta, leaves_p, new_params, lr,
                             n_workers)
        for i in plan.dense:
            g_mean = sync._dense_reduce(i, leaves_g)
            sync._dense_apply(i, g_mean, leaves_p, leaves_s, new_states,
                              new_params, lr)
        return (jax.tree.unflatten(treedef, new_params),
                jax.tree.unflatten(treedef, new_states))


class ChunkedSchedule:
    """§5.6 chunk-pipelined order: per chunk (reverse parameter order),
    compress then DISPATCH the transport immediately; drain unpack/apply
    after every chunk's collective is in flight. Bitwise identical to
    ``sequential`` — only dispatch count/order differ."""

    name = "chunked"

    def init_state(self, sync, params, leaf_state):
        return leaf_state

    def wrap_state_specs(self, leaf_specs, replicated):
        return leaf_specs

    def step(self, sync, grads, state, params, lr, density):
        all_dense = density >= 1.0
        (treedef, leaves_raw, leaves_g, leaves_p, leaves_s,
         n_workers) = sync._context(grads, state, params)
        # chunk layout + plans from the RAW leaves (§5.5 dispatch and
        # chunk byte budgeting on the true storage dtype)
        plans = sync._chunk_plans(grads, treedef, leaves_raw, density,
                                  all_dense)
        new_states = list(leaves_s)
        new_params = list(leaves_p)
        timer = sync.timer

        # dispatch loop: as soon as a chunk's gradients exist, issue its
        # select/mask/pack and its collective; do NOT consume any
        # gathered result yet (consuming would serialize the pipeline)
        inflight = []
        for cid, plan in enumerate(plans):
            timer.set_lane(f"chunk{cid}")
            msgs, meta = sync._compress_plan(
                plan, leaves_g, leaves_p, leaves_s, new_states)
            gathered = sync._gather(msgs) if msgs else []
            dense_means = [(i, sync._dense_reduce(i, leaves_g))
                           for i in plan.dense]
            timer.set_lane(None)
            inflight.append((cid, meta, gathered, dense_means))

        # drain loop: every chunk's collective has been issued; unpack
        # and apply in the same chunk order
        for cid, meta, gathered, dense_means in inflight:
            timer.set_lane(f"chunk{cid}")
            sync._apply_gathered(gathered, meta, leaves_p, new_params, lr,
                                 n_workers)
            for i, g_mean in dense_means:
                sync._dense_apply(i, g_mean, leaves_p, leaves_s,
                                  new_states, new_params, lr)
            timer.set_lane(None)
        return (jax.tree.unflatten(treedef, new_params),
                jax.tree.unflatten(treedef, new_states))


class Stale1Schedule:
    """One-step-delayed double-buffered sync (§5.6 maximal overlap).

    Step *t* packs its own messages into the pending buffer and
    communicates + applies the messages packed at step *t-1*. Dense
    leaves and the §5.7 dense warm-up stay synchronous."""

    name = "stale1"

    def init_state(self, sync, params, leaf_state):
        return ScheduleState(leaf=leaf_state,
                             pending=sync._pending_zeros(params))

    def wrap_state_specs(self, leaf_specs, replicated):
        # the pending wire messages are replicated like any packed
        # message (``replicated`` is a prefix spec over the whole tuple)
        return ScheduleState(leaf=leaf_specs, pending=replicated)

    def step(self, sync, grads, state, params, lr, density):
        if not isinstance(state, ScheduleState):
            raise TypeError(
                "stale1 schedule state must come from GradientSync.init "
                "(ScheduleState with a pending message buffer)")
        all_dense = density >= 1.0
        if not all_dense and density != sync.density:
            raise ValueError(
                f"stale1 requires a fixed target density (pending message "
                f"buffers are trace-time shapes): got step density "
                f"{density} vs configured {sync.density}. The §5.7 dense "
                f"warm-up (density >= 1.0) is supported; the DGC "
                f"intermediate-density ramp is not.")
        (treedef, leaves_raw, leaves_g, leaves_p, leaves_s,
         n_workers) = sync._context(grads, state.leaf, params)
        new_states = list(leaves_s)
        new_params = list(leaves_p)

        if all_dense:
            # §5.7 dense warm-up stage: every leaf synchronous dense
            # allreduce. The pending buffer is carried through UNCHANGED
            # — zero-count when warm-up precedes the first sparse step
            # (the normal case), and still holding a prior sparse step's
            # packed-but-unapplied values if a caller interleaves a
            # dense step mid-training: those values left the residual at
            # selection and may only be applied, never dropped, so they
            # ride along until the next sparse step communicates them.
            for i in range(len(leaves_g)):
                g_mean = sync._dense_reduce(i, leaves_g)
                sync._dense_apply(i, g_mean, leaves_p, leaves_s,
                                  new_states, new_params, lr)
            new_pending = state.pending
        else:
            # RAW-leaf plan: same key as the init-time _pending_zeros
            # plan, so the pending buffer layout always matches meta
            plan = sync._plan(grads, treedef, leaves_raw, density, False)
            # pack step t's messages (residual masked NOW, at selection)
            messages, meta = sync._compress_plan(
                plan, leaves_g, leaves_p, leaves_s, new_states)
            # ...but communicate and apply step t-1's buffer: the plan is
            # static across steps, so the meta describes both message sets
            gathered = sync._gather(list(state.pending))
            sync._apply_gathered(gathered, meta, leaves_p, new_params, lr,
                                 n_workers)
            for i in plan.dense:
                g_mean = sync._dense_reduce(i, leaves_g)
                sync._dense_apply(i, g_mean, leaves_p, leaves_s,
                                  new_states, new_params, lr)
            new_pending = tuple(messages)

        return (jax.tree.unflatten(treedef, new_params),
                ScheduleState(leaf=jax.tree.unflatten(treedef, new_states),
                              pending=new_pending))


@registry.register(registry.SCHEDULE, "sequential")
def _sequential(**_: Any) -> SequentialSchedule:
    return SequentialSchedule()


@registry.register(registry.SCHEDULE, "chunked")
def _chunked(**_: Any) -> ChunkedSchedule:
    return ChunkedSchedule()


@registry.register(registry.SCHEDULE, "stale1")
def _stale1(**_: Any) -> Stale1Schedule:
    return Stale1Schedule()
