"""Residual + momentum state for RGC (RedSync §5.7, Algorithm 4).

Per compressed leaf we keep:
  * ``residual``  V — locally accumulated un-communicated updates (f32)
  * ``momentum``  U — momentum-corrected velocity (f32); for *dense* (small)
                  leaves this doubles as the ordinary optimizer momentum
  * ``threshold`` — cached binary-search threshold (sampled variant, §5.2.2)
  * ``phase``     — top/bottom alternation for quantization (§5.2.3)
  * ``interval``  — iterations since the threshold was last refreshed

Momentum correction & momentum factor masking follow Lin et al. (2017) as
adopted by Alg 4 lines 8–23: velocity and residual accumulate *locally*, and
both are cleared at communicated coordinates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def pinned_product(a, b: jax.Array) -> jax.Array:
    """``a * b`` with separately-rounded (no-FMA) semantics, pinned.

    XLA:CPU/TPU may contract a multiply feeding an add into a fused
    multiply-add depending on which ops land in the same fusion — a
    decision that varies with the SURROUNDING graph. That makes
    ``m*U + g`` produce different last-ulp results in the per-leaf and
    flat-arena pipelines (and between eager and jit), breaking bitwise
    reproducibility of the residual state. Routing the product through a
    single-trip ``while_loop`` materializes it at a computation boundary
    no fusion (and therefore no contraction) can cross, pinning the
    eager two-rounding semantics everywhere. The loop bound is derived
    from the product's own bits (always 1, but not constant-foldable) so
    the while-loop simplifier cannot inline the identity body; the body
    is the identity, so the value is correct for ANY bound.
    """
    prod = a * b
    ib = jax.lax.bitcast_convert_type(
        prod.reshape(-1)[0].astype(jnp.float32), jnp.int32)
    bound = jnp.minimum(jnp.int32(1),
                        (ib & jnp.int32(0x3FFFFFFF)) + jnp.int32(1))

    def body(c):
        i, x = c
        # value-preserving but NOT loop-invariant: an identity carry would
        # be hoisted out of the while (reconnecting the multiply to its
        # consumer and re-enabling contraction); the runtime-true select
        # keeps the carry pinned inside the loop
        keep = i < jnp.int32(1 << 30)
        return i + jnp.int32(1), jnp.where(keep, x, jnp.zeros_like(x))

    return jax.lax.while_loop(lambda c: c[0] < bound, body,
                              (jnp.int32(0), prod))[1]


class LeafState(NamedTuple):
    residual: jax.Array    # f32 param-shaped
    momentum: jax.Array    # f32 param-shaped
    threshold: jax.Array   # f32 scalar
    phase: jax.Array       # i32 scalar
    interval: jax.Array    # i32 scalar


def init_leaf(param: jax.Array, *, momentum: bool = True,
              residual_dtype=jnp.float32) -> LeafState:
    """``momentum=False`` (vanilla-SGD RGC, the paper's LSTM runs) stores a
    scalar placeholder instead of a param-shaped velocity — halves RGC state
    memory. ``residual_dtype=bf16`` is the large-model memory adaptation
    (recorded per arch in EXPERIMENTS.md when used)."""
    v = jnp.zeros(param.shape, residual_dtype)
    u = jnp.zeros(param.shape, jnp.float32) if momentum else jnp.float32(0.0)
    return LeafState(v, u, jnp.float32(0.0), jnp.int32(0), jnp.int32(0))


def accumulate(
    grad: jax.Array,
    param: jax.Array,
    state: LeafState,
    *,
    momentum: float,
    nesterov: bool,
    weight_decay: float,
) -> LeafState:
    """Alg 4 lines 8–19: weight decay, momentum correction, residual add.

    The momentum / weight-decay products are contraction-pinned
    (``pinned_product``) so the accumulated state is bitwise identical
    whether this runs per leaf, per arena slot, eagerly or under jit.
    """
    g = grad.astype(jnp.float32)
    if weight_decay:
        g = g + pinned_product(weight_decay, param.astype(jnp.float32))
    r = state.residual.astype(jnp.float32)
    if momentum:
        u = pinned_product(momentum, state.momentum) + g
        v = r + u
        if nesterov:
            v = v + g
    else:
        u = state.momentum
        v = r + g
    return state._replace(residual=v.astype(state.residual.dtype),
                          momentum=u)


def mask_communicated(
    state: LeafState, indices: jax.Array, *, momentum: bool
) -> LeafState:
    """Alg 4 lines 21–23: clear V (and U) at communicated coordinates.

    ``indices`` may contain the padding sentinel (== size); 'drop' mode
    ignores those entries.
    """
    flat_v = state.residual.reshape(-1)
    v = flat_v.at[indices].set(0.0, mode="drop").reshape(state.residual.shape)
    if momentum:
        return mask_momentum(state._replace(residual=v), indices)
    return state._replace(residual=v)


def mask_momentum(state: LeafState, indices: jax.Array) -> LeafState:
    """DGC momentum factor masking: clear U at communicated coordinates.

    No-op for leaves without a param-shaped velocity (``momentum=False``
    init stores a scalar placeholder).
    """
    if getattr(state.momentum, "ndim", 0) == 0:
        return state
    flat_u = state.momentum.reshape(-1)
    u = flat_u.at[indices].set(0.0, mode="drop").reshape(state.momentum.shape)
    return state._replace(momentum=u)


def accumulate_arena(
    g2d: jax.Array,
    v2d: jax.Array,
    u2d: jax.Array | None,
    p2d: jax.Array | None,
    *,
    momentum: float,
    nesterov: bool,
    weight_decay: float,
    residual_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array | None]:
    """Alg 4 lines 8-19 over a whole residual arena (jnp twin of the
    fused ``kernels.segmented.seg_residual_update_stats`` pass).

    Elementwise math is exactly ``accumulate``'s, applied once per arena
    instead of once per leaf; ``residual_dtype`` rounds V' through the
    residual storage dtype so selection sees the same values the per-leaf
    path reloads from its state buffer. ``u2d`` is required iff
    ``momentum`` is nonzero, ``p2d`` iff ``weight_decay`` is nonzero.
    Returns (V', U' or None).
    """
    g = g2d.astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p2d.astype(jnp.float32)
    if momentum:
        u = momentum * u2d + g
        v = v2d + u
        if nesterov:
            v = v + g
    else:
        u = None
        v = v2d + g
    if residual_dtype != jnp.float32:
        v = v.astype(residual_dtype).astype(jnp.float32)
    return v, u


def local_clip_scale(grads_sq_sum: jax.Array, clip_norm: float,
                     num_workers: int) -> jax.Array:
    """DGC local gradient clipping (§5.6): clip the *local* gradient to
    N^{-1/2} of the global threshold before residual accumulation."""
    norm = jnp.sqrt(grads_sq_sum)
    limit = clip_norm / jnp.sqrt(jnp.float32(num_workers))
    return jnp.minimum(1.0, limit / jnp.maximum(norm, 1e-12))
