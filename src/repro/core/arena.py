"""Flat residual arenas: coalesced storage views for the sparse sync path.

RedSync's cost decomposition (§5.2–§5.5, Fig 10) shows selection/packing
overhead — not just wire time — eroding compression gains, and the DGC /
gradient-compression-systems literature pins the culprit: running the
mask → select → pack pipeline **once per tensor** costs O(leaves) kernel
launches and per-leaf intermediates per step. This module provides the
layout half of the fix: all sparse-path leaves of the same dtype and
selection algorithm are coalesced into a small number of contiguous f32
*arenas*, so each pipeline stage runs once per arena while selection
stays *segmented* (each leaf keeps its own ``k_i``, selected within its
own segment — the communicated set is bitwise identical to the per-leaf
path; see ``repro.kernels.segmented``).

Layout invariants (property-tested in tests/test_arena.py):

* every slot's ``offset`` is ``ARENA_BLOCK``-aligned and slots never
  overlap: slot ``i`` occupies ``[offset, offset + padded)`` with
  ``padded = ceil(size / ARENA_BLOCK) * ARENA_BLOCK``;
* the inter-slot padding is zero-filled, so a slot's padded 2-D view
  ``[nblocks, ARENA_BLOCK]`` is bit-for-bit the same array the per-leaf
  Pallas/jnp selectors build for that leaf on its own (this is what makes
  segmented block statistics reproduce per-leaf statistics BITWISE);
* ``gather`` then ``scatter`` round-trips leaf values exactly;
* one arena never mixes gradient dtypes or selection algorithms.

The block granule matches ``kernels.ops.DEFAULT_BLOCK`` and
``selection.STATS_BLOCK`` — one constant, three views of it.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import cached_property
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import sync as sync_lib
from .selection import STATS_BLOCK, Selected

ARENA_BLOCK = STATS_BLOCK      # element alignment of arena slots


def padded_size(n: int, block: int = ARENA_BLOCK) -> int:
    return max(1, -(-n // block)) * block


@dataclass(frozen=True)
class Slot:
    """One leaf's segment of an arena (all static trace-time metadata)."""

    leaf: int          # position in the flattened gradient tree
    path: str
    offset: int        # element offset into the arena (ARENA_BLOCK-aligned)
    size: int          # true element count
    padded: int        # size rounded up to ARENA_BLOCK
    row0: int          # first row of the arena's [nblocks, ARENA_BLOCK] view
    k: int             # per-leaf selection target
    capacity: int      # message capacity (compressor.capacity(k))
    msg_offset: int    # element offset into the arena's wire message
    msg_len: int       # sync.message_len(capacity, quantized)

    @property
    def nblocks(self) -> int:
        return self.padded // ARENA_BLOCK

    @property
    def rows(self) -> tuple[int, int]:
        return self.row0, self.row0 + self.nblocks


@dataclass(frozen=True)
class SegmentGeometry:
    """Per-block segment maps the segmented kernels consume (numpy, static).

    ``block_seg[b]`` is the slot ordinal owning arena row ``b``;
    ``block_base[b]`` is that row's first LOCAL element index within its
    slot; ``block_size[b]`` is the owning slot's true size (the bounds
    check / padding sentinel — identical to the per-leaf kernels'
    ``total``).
    """

    block: int
    n_seg: int
    nblocks: int
    block_seg: np.ndarray    # [nblocks] i32
    block_base: np.ndarray   # [nblocks] i32
    block_size: np.ndarray   # [nblocks] i32
    seg_sizes: tuple[int, ...]
    seg_ks: tuple[int, ...]
    seg_rows: tuple[tuple[int, int], ...]


def single_slot_geometry(n: int, k: int,
                         block: int = ARENA_BLOCK) -> SegmentGeometry:
    """A one-slot geometry viewing a lone flat leaf as a mini arena.

    Lets the per-leaf compressor paths reuse the segmented kernels (the
    sampled selector's strided kernels exist only in segmented form) —
    the slot's padded 2-D view is exactly the per-leaf kernels' own
    ``_to2d`` layout, so nothing changes but the entry point.
    """
    nb = max(1, -(-n // block))
    return SegmentGeometry(
        block=block, n_seg=1, nblocks=nb,
        block_seg=np.zeros(nb, np.int32),
        block_base=np.arange(nb, dtype=np.int32) * block,
        block_size=np.full(nb, n, np.int32),
        seg_sizes=(n,), seg_ks=(k,), seg_rows=((0, nb),))


def stack_geometries(geoms: Sequence[SegmentGeometry]) -> SegmentGeometry:
    """Row-concatenate several arenas' geometries into one super-arena.

    Segment ordinals and row ranges are offset so the combined maps
    address the vertically stacked ``[sum nblocks, block]`` value array.
    Per-segment kernel results (stats, counts, buckets) are independent
    of which rows belong to *other* segments, so running the segmented
    kernels once over the stack is bitwise running them per arena — this
    is what lets ``select`` across all arenas of a step issue a single
    dispatch per search iteration.
    """
    if not geoms:
        raise ValueError("stack_geometries needs at least one geometry")
    block = geoms[0].block
    if any(g.block != block for g in geoms):
        raise ValueError("cannot stack geometries with different blocks")
    seg_parts, rows_parts = [], []
    seg_off = row_off = 0
    sizes: tuple[int, ...] = ()
    ks: tuple[int, ...] = ()
    for g in geoms:
        seg_parts.append(np.asarray(g.block_seg, np.int32) + seg_off)
        rows_parts.extend((r0 + row_off, r1 + row_off) for r0, r1 in g.seg_rows)
        sizes += tuple(g.seg_sizes)
        ks += tuple(g.seg_ks)
        seg_off += g.n_seg
        row_off += g.nblocks
    return SegmentGeometry(
        block=block, n_seg=seg_off, nblocks=row_off,
        block_seg=np.concatenate(seg_parts),
        block_base=np.concatenate(
            [np.asarray(g.block_base, np.int32) for g in geoms]),
        block_size=np.concatenate(
            [np.asarray(g.block_size, np.int32) for g in geoms]),
        seg_sizes=sizes, seg_ks=ks, seg_rows=tuple(rows_parts))


@dataclass(frozen=True)
class ArenaGroup:
    """A contiguous f32 arena over same-dtype, same-compressor leaves."""

    aid: int
    compressor: str               # registered compressor name
    dtype: str                    # gradient dtype the arena coalesces
    slots: tuple[Slot, ...]

    @property
    def total(self) -> int:
        last = self.slots[-1]
        return last.offset + last.padded

    @property
    def nblocks(self) -> int:
        return self.total // ARENA_BLOCK

    @property
    def msg_total(self) -> int:
        last = self.slots[-1]
        return last.msg_offset + last.msg_len

    @cached_property
    def geometry(self) -> SegmentGeometry:
        seg = np.empty(self.nblocks, np.int32)
        base = np.empty(self.nblocks, np.int32)
        size = np.empty(self.nblocks, np.int32)
        for s_ord, slot in enumerate(self.slots):
            r0, r1 = slot.rows
            seg[r0:r1] = s_ord
            base[r0:r1] = (np.arange(slot.nblocks, dtype=np.int32)
                           * ARENA_BLOCK)
            size[r0:r1] = slot.size
        return SegmentGeometry(
            block=ARENA_BLOCK, n_seg=len(self.slots), nblocks=self.nblocks,
            block_seg=seg, block_base=base, block_size=size,
            seg_sizes=tuple(s.size for s in self.slots),
            seg_ks=tuple(s.k for s in self.slots),
            seg_rows=tuple(s.rows for s in self.slots))


def build_group(aid: int, compressor: str, dtype: str,
                leaves: Sequence[tuple[int, str, int, int, int, int]]
                ) -> ArenaGroup:
    """Lay out one arena. ``leaves`` holds per-slot
    ``(leaf_index, path, size, k, capacity, msg_len)`` in tree order."""
    slots = []
    off = row = moff = 0
    for leaf, path, size, k, capacity, msg_len in leaves:
        pad = padded_size(size)
        slots.append(Slot(leaf=leaf, path=path, offset=off, size=size,
                          padded=pad, row0=row, k=k, capacity=capacity,
                          msg_offset=moff, msg_len=msg_len))
        off += pad
        row += pad // ARENA_BLOCK
        moff += msg_len
    return ArenaGroup(aid=aid, compressor=compressor, dtype=dtype,
                      slots=tuple(slots))


# -- gather / scatter views -------------------------------------------------

def gather(group: ArenaGroup, arrays: Sequence[Any]) -> jax.Array:
    """Leaf arrays (indexed by tree position) -> [nblocks, ARENA_BLOCK] f32.

    Each slot is flattened, upcast to f32 and zero-padded to its padded
    extent — bit-for-bit the 2-D view the per-leaf selectors build.
    """
    pieces = []
    for slot in group.slots:
        a = arrays[slot.leaf].reshape(-1).astype(jnp.float32)
        pieces.append(jnp.pad(a, (0, slot.padded - slot.size)))
    return jnp.concatenate(pieces).reshape(group.nblocks, ARENA_BLOCK)


def scatter(group: ArenaGroup, arena2d: jax.Array) -> dict[int, jax.Array]:
    """Arena view -> {leaf_index: flat f32[size]} (inverse of ``gather``
    up to the zero padding, which is dropped)."""
    flat = arena2d.reshape(-1)
    return {slot.leaf: flat[slot.offset:slot.offset + slot.size]
            for slot in group.slots}


def communicated_indices(group: ArenaGroup,
                         selected: Sequence[Selected]) -> jax.Array:
    """Slot-local selected indices -> one arena-global index vector.

    Padding sentinels (local index == slot size) are mapped past the
    arena's end so a single ``mode="drop"`` scatter clears every slot's
    communicated coordinates without touching a neighbour's padding.
    """
    total = group.total
    out = []
    for slot, sel in zip(group.slots, selected):
        out.append(jnp.where(sel.indices < slot.size,
                             sel.indices + slot.offset, total))
    return jnp.concatenate(out)


def mask_arena(arena2d: jax.Array, global_idx: jax.Array) -> jax.Array:
    """Clear the communicated coordinates of one arena (Alg 4 l.21-23,
    once per arena instead of once per leaf)."""
    flat = arena2d.reshape(-1)
    return flat.at[global_idx].set(0.0, mode="drop").reshape(arena2d.shape)


# -- wire format ------------------------------------------------------------

def pack_group(group: ArenaGroup, selected: Sequence[Selected]) -> jax.Array:
    """All slot messages -> ONE packed wire buffer for the transport.

    The buffer is the slot-order concatenation of exactly the per-leaf
    ``sync.pack`` messages (``sync.pack_pieces`` owns the layout), so
    gathered bytes split per slot are bitwise what the per-leaf path
    transfers. One concatenate replaces O(leaves) pack dispatches.
    """
    pieces = []
    for sel in selected:
        pieces.extend(sync_lib.pack_pieces(sel, quantized=False))
    return jnp.concatenate(pieces)


def split_message(group: ArenaGroup, gathered: jax.Array
                  ) -> list[jax.Array]:
    """[workers, msg_total] gathered arena buffer -> per-slot segments."""
    return [gathered[:, s.msg_offset:s.msg_offset + s.msg_len]
            for s in group.slots]
