"""RedSync core: composable residual gradient compression.

Layering:
  * ``registry``      — string-addressable component registry
  * ``api``           — ``Compressor`` / ``Transport`` / ``DispatchPolicy``
                        / ``Correction`` protocols
  * ``arena``         — flat residual arenas: coalesced same-dtype slot
                        layout + gather/scatter views for the fused
                        select/mask/pack path (``fuse_leaves``)
  * ``compressors``   — dense / exact_topk / trimmed_topk /
                        threshold_bsearch / quantized(inner)
  * ``correction``    — momentum / factor_masking / local_clip / warmup
                        (DGC convergence corrections + spec grammar)
  * ``transport``     — fused_allgather / bucketed_allgather /
                        hierarchical / per_leaf_allgather / dense_psum
  * ``instrument``    — StageTimer implementations (NullTimer /
                        WallClockTimer) for the Fig 10 stage decomposition
  * ``dispatch``      — size_based (§5.5, real dtype bytes) / fixed
  * ``overlap``       — §5.6 overlap schedules: sequential / chunked
                        (reverse-order chunk pipelining) / stale1
                        (one-step-delayed double buffering)
  * ``gradient_sync`` — the composed optax-style transform
  * ``rgc``           — legacy ``rgc_init``/``rgc_apply`` shims
"""
from . import registry
from .api import (Compressor, Correction, DispatchPolicy, Schedule,
                  StageTimer, Transport)
from .compressors import Dense, ExactTopK, Quantized, ThresholdBSearch, \
    TrimmedTopK
from .correction import (CorrectionBase, FactorMasking, LocalClip,
                         MomentumCorrection, Warmup, split_corrections)
from .cost_model import (NetworkModel, PRESETS, choose_method, eq1_terms,
                         predicted_shares, speedup, t_dense, t_select_model,
                         t_sparse)
from .dispatch import FixedPolicy, SizeBasedPolicy, leaf_nbytes
from .gradient_sync import GradientSync, build_gradient_sync
from .instrument import STAGES, NullTimer, WallClockTimer
from .overlap import (Chunk, ChunkedSchedule, ScheduleState,
                      SequentialSchedule, Stale1Schedule, partition_chunks)
from .rgc import RGCConfig, gradient_sync_from_rgc_config, rgc_apply, rgc_init
from .schedule import DensitySchedule
from .selection import (Selected, exact_topk, exact_topk_quant,
                        threshold_binary_search, threshold_binary_search_quant,
                        threshold_filter, trimmed_topk, trimmed_topk_quant)
from .transport import (BucketedAllgather, DensePsum, FusedAllgather,
                        HierarchicalAllgather, PerLeafAllgather,
                        assign_buckets)

__all__ = [
    "registry",
    "Compressor", "Correction", "DispatchPolicy", "Schedule", "StageTimer",
    "Transport",
    "Dense", "ExactTopK", "Quantized", "ThresholdBSearch", "TrimmedTopK",
    "CorrectionBase", "FactorMasking", "LocalClip", "MomentumCorrection",
    "Warmup", "split_corrections",
    "NetworkModel", "PRESETS", "choose_method", "eq1_terms",
    "predicted_shares", "speedup", "t_dense", "t_select_model", "t_sparse",
    "FixedPolicy", "SizeBasedPolicy", "leaf_nbytes",
    "GradientSync", "build_gradient_sync",
    "STAGES", "NullTimer", "WallClockTimer",
    "Chunk", "ChunkedSchedule", "ScheduleState", "SequentialSchedule",
    "Stale1Schedule", "partition_chunks",
    "RGCConfig", "gradient_sync_from_rgc_config", "rgc_apply", "rgc_init",
    "DensitySchedule",
    "Selected", "exact_topk", "exact_topk_quant", "threshold_binary_search",
    "threshold_binary_search_quant", "threshold_filter", "trimmed_topk",
    "trimmed_topk_quant",
    "BucketedAllgather", "DensePsum", "FusedAllgather",
    "HierarchicalAllgather", "PerLeafAllgather", "assign_buckets",
]
