"""RedSync core: residual gradient compression, sparse sync, cost model."""
from .cost_model import (NetworkModel, PRESETS, choose_method, speedup,
                         t_dense, t_sparse)
from .rgc import RGCConfig, rgc_apply, rgc_init
from .schedule import DensitySchedule
from .selection import (Selected, exact_topk, exact_topk_quant,
                        threshold_binary_search, threshold_binary_search_quant,
                        threshold_filter, trimmed_topk, trimmed_topk_quant)

__all__ = [
    "NetworkModel", "PRESETS", "choose_method", "speedup", "t_dense",
    "t_sparse", "RGCConfig", "rgc_apply", "rgc_init", "DensitySchedule",
    "Selected", "exact_topk", "exact_topk_quant", "threshold_binary_search",
    "threshold_binary_search_quant", "threshold_filter", "trimmed_topk",
    "trimmed_topk_quant",
]
