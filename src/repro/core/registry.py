"""String-addressable component registry for the compression API.

Every pluggable piece of the gradient-sync pipeline — ``Compressor``,
``Transport``, ``DispatchPolicy``, ``Correction``, ``Schedule`` —
registers a factory
under a ``(kind, name)`` key so configs can name components by string
(``TrainConfig.optimizer = "threshold_bsearch"``) and extensions can add
new ones without touching core code:

    from repro.core import registry

    @registry.register(registry.COMPRESSOR, "my_topk")
    class MyTopK: ...

    comp = registry.make(registry.COMPRESSOR, "my_topk", eps=0.1)

Specs support one level of composition with ``outer(inner)`` syntax —
``"quantized(trimmed_topk)"`` builds the inner compressor first and passes
it to the outer factory as the ``inner`` keyword (RedSync §5.2.3 wraps any
selector). Factories receive ``**params`` and must ignore keys they don't
consume, so one config bag can parameterize heterogeneous components.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable

COMPRESSOR = "compressor"
TRANSPORT = "transport"
DISPATCH_POLICY = "dispatch_policy"
CORRECTION = "correction"
SCHEDULE = "schedule"

_REGISTRY: dict[str, dict[str, Callable[..., Any]]] = {}


def register(kind: str, name: str) -> Callable[[Callable], Callable]:
    """Decorator: register ``factory`` under ``(kind, name)``.

    Re-registering a name overwrites (supports reload / test doubles).
    """
    def deco(factory: Callable) -> Callable:
        _REGISTRY.setdefault(kind, {})[name] = factory
        return factory
    return deco


def register_alias(kind: str, alias: str, name: str) -> None:
    """Expose an already-registered factory under a second name."""
    _REGISTRY.setdefault(kind, {})[alias] = _REGISTRY[kind][name]


def names(kind: str) -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY.get(kind, {})))


def contains(kind: str, spec: str) -> bool:
    try:
        parse(kind, spec)
        return True
    except KeyError:
        return False


def parse(kind: str, spec: str) -> tuple[Callable, str | None]:
    """``"name"`` or ``"outer(inner)"`` -> (outer factory, inner spec)."""
    spec = spec.strip()
    inner: str | None = None
    if spec.endswith(")") and "(" in spec:
        spec, _, rest = spec.partition("(")
        inner = rest[:-1].strip()
    table = _REGISTRY.get(kind, {})
    if spec not in table:
        raise KeyError(
            f"no {kind} named {spec!r}; registered: {names(kind)}")
    if inner is not None:                 # validate the inner spec eagerly
        parse(kind, inner)
    return table[spec], inner


def make(kind: str, spec: str, **params: Any) -> Any:
    """Build a component from a string spec, threading ``params`` through."""
    factory, inner = parse(kind, spec)
    if inner is not None:
        return factory(inner=make(kind, inner, **params), **params)
    return factory(**params)
