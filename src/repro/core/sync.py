"""Sparse synchronization (RedSync §5.3/§5.4).

Message wire format (f32 vector, fixed capacity at trace time — the paper's
"(length, indices, values) packed into a single message"):

    [ count (i32 bitcast) | indices (i32 bitcast) x cap | payload ]

payload = values x cap (plain RGC) or a single scalar mean (quantized RGC).
Packing indices+values into ONE buffer mirrors §5.3 (single allgather instead
of two) and, on TPU, emits one ICI all-gather per fused group instead of two.

Tensor fusion (§5.3 "batch small allgather operations"): callers concatenate
many leaf messages into one flat buffer and allgather once; ``split_counts``
recovers the per-leaf segments.

Decompression (§5.4): scatter-add each worker's sparse message into the dense
f32 update — XLA scatter is the TPU-native cuSparse-axpyi analogue.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .selection import Selected


def _i2f(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x.astype(jnp.int32), jnp.float32)


def _f2i(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def message_len(capacity: int, quantized: bool) -> int:
    return 1 + capacity + (1 if quantized else capacity)


def pack_pieces(sel: Selected, quantized: bool) -> list[jax.Array]:
    """The wire-format segments of one message, in order (the single
    definition of the layout): ``[count | indices | payload]``. Callers
    concatenate — ``pack`` for one message, ``arena.pack_group`` for a
    whole arena's slot messages in one concatenate."""
    header = _i2f(sel.count[None])
    idx = _i2f(sel.indices)
    if quantized:
        denom = jnp.maximum(sel.count, 1).astype(jnp.float32)
        mean = (jnp.sum(sel.values) / denom)[None]
        return [header, idx, mean]
    return [header, idx, sel.values]


def pack(sel: Selected, quantized: bool) -> jax.Array:
    """Selected -> packed f32 wire message."""
    return jnp.concatenate(pack_pieces(sel, quantized))


def unpack_decompress(
    gathered: jax.Array, size: int, capacity: int, quantized: bool
) -> jax.Array:
    """[num_workers, msg_len] -> dense f32[size] SUM of all sparse messages.

    Padding indices (== size) and slots beyond each worker's ``count`` are
    dropped. Caller divides by N for the mean (Alg 1 line 7).
    """
    p = gathered.shape[0]
    counts = _f2i(gathered[:, 0])                      # [p]
    idx = _f2i(gathered[:, 1 : 1 + capacity])          # [p, cap]
    slot = jnp.arange(capacity)[None, :]
    live = slot < counts[:, None]
    if quantized:
        vals = jnp.broadcast_to(gathered[:, 1 + capacity][:, None], idx.shape)
    else:
        vals = gathered[:, 1 + capacity : 1 + 2 * capacity]
    # send dead slots out of range so 'drop' discards them
    idx = jnp.where(live, idx, size)
    dense = jnp.zeros((size,), jnp.float32)
    return dense.at[idx.reshape(-1)].add(vals.reshape(-1), mode="drop")


def sparse_allgather(msg: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """All-gather one packed message across the data-parallel mesh axes.

    Returns [num_workers, msg_len] with num_workers = prod(axis sizes).
    Empty ``axes`` (single-worker smoke paths) is the identity.
    """
    if not axes:
        return msg[None]
    name = axes if len(axes) > 1 else axes[0]
    out = jax.lax.all_gather(msg, name)
    return out.reshape(-1, msg.shape[0])


def fused_allgather(messages: list[jax.Array], axes: tuple[str, ...]) -> list[jax.Array]:
    """Tensor fusion: concat all leaf messages -> ONE allgather -> split."""
    lens = [int(m.shape[0]) for m in messages]
    buf = jnp.concatenate(messages)
    gathered = sparse_allgather(buf, axes)             # [p, sum(lens)]
    return split_rows(gathered, lens)


def split_rows(gathered: jax.Array, lens: list[int]) -> list[jax.Array]:
    """[p, sum(lens)] fused buffer -> per-leaf [p, len] segments."""
    out, off = [], 0
    for length in lens:
        out.append(gathered[:, off : off + length])
        off += length
    return out


def hierarchical_allgather(msg: jax.Array, inter_axes: tuple[str, ...],
                           intra_axis: str | None,
                           sync_axes: tuple[str, ...] | None = None
                           ) -> jax.Array:
    """§5.4 two-level exchange: inter-node sparse allgather + intra-node
    dense psum.

    Hop 1 gathers the packed sparse messages over the (slow) inter-node
    axes only — each worker receives the messages of its same-local-rank
    peer on every node, so the expensive hop carries p/n_local messages
    instead of p. Hop 2 reassembles the full [p, len] message matrix over
    the (fast) intra-node axis as a dense psum: every worker scatters its
    inter-gathered rows into a zero-initialized full buffer at its own
    local-rank slot and the psum sums the disjoint contributions.

    The psum runs on the buffer bitcast to int32: each matrix entry is
    written by exactly one local worker (the rest contribute integer
    zeros), so integer addition makes the reassembly an exact bit move.
    An f32 psum would corrupt the message — the wire format embeds
    bitcast-int32 counts/indices whose f32 views are denormals, and
    backends running flush-to-zero (XLA:CPU reductions do) would zero
    them. Downstream decompression therefore sees byte-identical input to
    a flat ``sparse_allgather`` over the FULL axis tuple: rows come out
    inter-major, and when ``sync_axes`` names an order with the intra
    axis elsewhere than last (``jax.lax.all_gather`` over the joint axes
    is first-axis-major), the block is transposed back into that order —
    so parity with the flat gather holds for any ``intra_axis`` choice.
    """
    if intra_axis is None:
        return sparse_allgather(msg, inter_axes)
    if not inter_axes:
        return sparse_allgather(msg, (intra_axis,))
    from repro.jaxcompat import axis_size
    g_inter = sparse_allgather(msg, inter_axes)        # [n_inter, len]
    n_local = axis_size(intra_axis)
    my_rank = jax.lax.axis_index(intra_axis)
    full = jnp.zeros((g_inter.shape[0], n_local, g_inter.shape[1]),
                     jnp.int32)
    full = jax.lax.dynamic_update_slice_in_dim(
        full, _f2i(g_inter)[:, None, :], my_rank, axis=1)
    full = jax.lax.psum(full, intra_axis)
    out = _i2f(full)                                   # [n_inter, n_local, L]
    if sync_axes and tuple(sync_axes) != tuple(inter_axes) + (intra_axis,):
        sizes = [axis_size(a) for a in inter_axes]
        out = out.reshape(*sizes, n_local, out.shape[-1])
        out = jnp.moveaxis(out, len(sizes), sync_axes.index(intra_axis))
    return out.reshape(-1, msg.shape[0])


def dense_allreduce_mean(grad: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """Paper's dense fallback / baseline: allreduce-mean over workers."""
    g = grad.astype(jnp.float32)
    return jax.lax.pmean(g, axes) if axes else g
