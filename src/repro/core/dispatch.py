"""Per-leaf method dispatch (§5.5), as pluggable ``DispatchPolicy``s.

``size_based`` is the paper's rule — < 128 KB dense allreduce; 128 KB –
4 MB trimmed top-k; > 4 MB sampled threshold binary search — driven by
the leaf's REAL byte size (``size * dtype.itemsize``). The seed's
``leaf_bytes`` assumed 4 bytes/element, which mis-dispatched bf16 models
across both boundaries (a 96 K-element bf16 leaf is 187.5 KB, not 375 KB).
Wire messages are still f32 regardless of the gradient dtype
(``sync.py``); the dispatch question is about the *parameter's* traffic
volume, which follows its storage size.

``fixed`` routes every leaf through one named compressor — what
``TrainConfig.optimizer = "<registered name>"`` builds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax

from . import registry
from .cost_model import (DENSE_THRESHOLD_BYTES, TRIMMED_THRESHOLD_BYTES,
                         choose_method)

# cost-model method name -> registered compressor name
_METHOD_COMPRESSOR = {
    "dense": "dense",
    "trimmed_topk": "trimmed_topk",
    "threshold_binary_search": "threshold_bsearch",
}


def leaf_nbytes(x: jax.Array) -> int:
    """Real storage bytes of a leaf (works on ShapeDtypeStruct too)."""
    import numpy as np
    return int(x.size) * np.dtype(x.dtype).itemsize


@dataclass(frozen=True)
class SizeBasedPolicy:
    """RedSync §5.5: choose the selector by leaf byte size.

    Delegates to ``cost_model.choose_method`` so the model and the live
    dispatch share ONE definition of the (half-open) boundaries: exactly
    128 KB → trimmed top-k, exactly 4 MB → binary search, 0 bytes → dense.
    """

    dense_threshold_bytes: int = DENSE_THRESHOLD_BYTES
    trimmed_threshold_bytes: int = TRIMMED_THRESHOLD_BYTES

    def compressor_for(self, path: str, leaf: jax.Array) -> str:
        method = choose_method(leaf_nbytes(leaf), self.dense_threshold_bytes,
                               self.trimmed_threshold_bytes)
        return _METHOD_COMPRESSOR[method]


@dataclass(frozen=True)
class FixedPolicy:
    """Every leaf uses one registered compressor (benchmark / ablation)."""

    compressor: str = "threshold_bsearch"

    def compressor_for(self, path: str, leaf: jax.Array) -> str:
        return self.compressor


@registry.register(registry.DISPATCH_POLICY, "size_based")
def _size_based(dense_threshold_bytes: int = DENSE_THRESHOLD_BYTES,
                trimmed_threshold_bytes: int = TRIMMED_THRESHOLD_BYTES,
                **_: Any) -> SizeBasedPolicy:
    return SizeBasedPolicy(dense_threshold_bytes, trimmed_threshold_bytes)


@registry.register(registry.DISPATCH_POLICY, "fixed")
def _fixed(compressor: str = "threshold_bsearch", **_: Any) -> FixedPolicy:
    return FixedPolicy(compressor)
