"""Compressor implementations (RedSync §5.2), registry-addressable.

Each compressor owns one leaf's selection semantics and its share of the
wire protocol (capacity + decompression); packing and collectives live in
``transport``. All implementations are stateless Python objects — JAX
state (threshold cache, quantization phase, bsearch refresh interval)
rides in the per-leaf ``LeafState``.

Registered names: ``dense``, ``exact_topk``, ``trimmed_topk``,
``threshold_bsearch`` (alias ``threshold_binary_search``), and the
``quantized(<inner>)`` wrapper. Factories accept the shared parameter bag
(``backend``, ``bsearch_interval``, ...) and ignore what they don't use,
so ``registry.make(COMPRESSOR, name, **params)`` works uniformly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import registry
from . import selection as sel_lib
from . import sync as sync_lib
from .residual import LeafState, init_leaf
from .selection import Selected


class _Base:
    """Shared init/decompress; subclasses define capacity + compress.

    Compressors with a segmented implementation (``supports_segmented``)
    additionally expose ``compress_segments``: Algorithm 2/3 over every
    slot of a flat residual arena at once (``repro.core.arena`` /
    ``repro.kernels.segmented``), bitwise identical to calling
    ``compress`` per leaf. Leaves whose compressor lacks one (exact_topk,
    quantized wrappers, custom compressors) simply stay on the per-leaf
    path when arenas are enabled.
    """

    name = "?"
    quantized = False
    supports_segmented = False

    def init_leaf(self, param: jax.Array, *, momentum: bool,
                  residual_dtype: Any = jnp.float32) -> LeafState:
        return init_leaf(param, momentum=momentum,
                         residual_dtype=residual_dtype)

    def decompress(self, gathered: jax.Array, size: int, k: int) -> jax.Array:
        return sync_lib.unpack_decompress(
            gathered, size, self.capacity(k), self.quantized)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<compressor {self.name}>"


class Dense(_Base):
    """Sentinel: leaf takes the dense allreduce path (no sparse message).

    ``GradientSync`` routes "dense" leaves through
    ``Transport.allreduce_mean`` + plain momentum SGD; compress/decompress
    are never called.
    """

    name = "dense"

    def capacity(self, k: int) -> int:
        return 0

    def compress(self, flat_v, k, state):
        raise NotImplementedError(
            "dense leaves are synchronized via Transport.allreduce_mean")


class ExactTopK(_Base):
    """The radixSelect baseline: exact |x| top-k (capacity == k)."""

    name = "exact_topk"

    def capacity(self, k: int) -> int:
        return k

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        return sel_lib.exact_topk(flat_v, k), state

    def quant_select(self, flat_v: jax.Array, k: int,
                     phase: jax.Array) -> Selected:
        return sel_lib.exact_topk_quant(flat_v, k, phase)


class TrimmedTopK(_Base):
    """Alg 2: statistics-guided trimming, then top-k over survivors."""

    name = "trimmed_topk"
    supports_segmented = True

    def __init__(self, backend: str = "jnp", eps: float = 0.2):
        self.backend = backend
        self.eps = eps

    def capacity(self, k: int) -> int:
        return k

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.trimmed_topk(flat_v, k), state
        return sel_lib.trimmed_topk(flat_v, k, self.eps), state

    def compress_segments(self, x2d, geom, states, stats=None):
        """Alg 2 over one arena; mirrors ``compress`` per backend (the
        pallas per-leaf path uses the kernel-default eps)."""
        from repro.kernels import segmented as kseg
        use_pallas = self.backend == "pallas"
        sel = kseg.trimmed_topk_segments(
            x2d, geom, use_pallas=use_pallas, stats=stats,
            **({} if use_pallas else {"eps": self.eps}))
        return sel, list(states)

    def quant_select(self, flat_v: jax.Array, k: int,
                     phase: jax.Array) -> Selected:
        return sel_lib.trimmed_topk_quant(flat_v, k, phase, self.eps)


class ThresholdBSearch(_Base):
    """Alg 3: sampled threshold binary search with threshold reuse.

    capacity == 2k (padded; true length in the ``count`` header). The
    binary search refreshes every ``interval`` iterations and reuses the
    cached ``LeafState.threshold`` in between (§5.2.2 "sampled" variant).
    """

    name = "threshold_bsearch"
    supports_segmented = True

    def __init__(self, backend: str = "jnp", interval: int = 5,
                 eps: float = 1e-3):
        self.backend = backend
        self.interval = interval
        self.eps = eps

    def capacity(self, k: int) -> int:
        return 2 * k

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            selected, thr = kops.threshold_binary_search(flat_v, k)
            return selected, state._replace(threshold=thr)

        def refresh(_):
            s, thr = sel_lib.threshold_binary_search(flat_v, k, self.eps)
            return s, thr

        def reuse(_):
            s = sel_lib.threshold_filter(flat_v, state.threshold,
                                         capacity=2 * k)
            return s, state.threshold

        do_refresh = (state.interval % self.interval) == 0
        s, thr = jax.lax.cond(do_refresh, refresh, reuse, operand=None)
        return s, state._replace(threshold=thr,
                                 interval=state.interval + 1)

    def compress_segments(self, x2d, geom, states, stats=None):
        """Alg 3 over one arena; mirrors ``compress`` per backend: the
        pallas path always re-searches (kernel defaults, interval
        untouched), the jnp path applies §5.2.2 threshold reuse per
        segment from the cached LeafState scalars."""
        import jax.numpy as jnp_

        from repro.kernels import segmented as kseg
        if self.backend == "pallas":
            sel, thr = kseg.threshold_bsearch_segments(
                x2d, geom, use_pallas=True, stats=stats)
            return sel, [st._replace(threshold=thr[i])
                         for i, st in enumerate(states)]
        intervals = jnp_.stack([st.interval for st in states])
        cached = jnp_.stack([st.threshold for st in states])
        refresh = (intervals % self.interval) == 0
        sel, thr = kseg.threshold_bsearch_segments(
            x2d, geom, eps=self.eps, use_pallas=False, stats=stats,
            refresh=refresh, cached=cached)
        return sel, [st._replace(threshold=thr[i],
                                 interval=st.interval + 1)
                     for i, st in enumerate(states)]

    def quant_select(self, flat_v: jax.Array, k: int,
                     phase: jax.Array) -> Selected:
        # threshold sharing is incompatible with the alternating sign
        # phase (§5.2.3), so the quantized variant always re-searches.
        return sel_lib.threshold_binary_search_quant(flat_v, k, phase,
                                                     self.eps)


class Quantized(_Base):
    """§5.2.3 wrapper: same-signed selection + single-scalar-mean payload.

    Alternates top-k (positives) and bottom-k (negatives) via
    ``LeafState.phase``; the wire message carries (count, indices, mean)
    — ``sync.pack``/``unpack_decompress`` handle the payload swap via the
    ``quantized`` flag.
    """

    quantized = True

    def __init__(self, inner: _Base):
        if getattr(inner, "quantized", False):
            raise ValueError("cannot quantize an already-quantized "
                             f"compressor {inner.name!r}")
        if not hasattr(inner, "quant_select"):
            raise ValueError(
                f"compressor {inner.name!r} has no quantized variant")
        self.inner = inner
        self.name = f"quantized({inner.name})"

    def capacity(self, k: int) -> int:
        return self.inner.capacity(k)

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        sel = self.inner.quant_select(flat_v, k, state.phase)
        return sel, state._replace(phase=(state.phase + 1) % 2)


# --- registration ----------------------------------------------------------

@registry.register(registry.COMPRESSOR, "dense")
def _dense(**_: Any) -> Dense:
    return Dense()


@registry.register(registry.COMPRESSOR, "exact_topk")
def _exact(**_: Any) -> ExactTopK:
    return ExactTopK()


@registry.register(registry.COMPRESSOR, "trimmed_topk")
def _trimmed(backend: str = "jnp", trim_eps: float = 0.2,
             **_: Any) -> TrimmedTopK:
    return TrimmedTopK(backend=backend, eps=trim_eps)


@registry.register(registry.COMPRESSOR, "threshold_bsearch")
def _bsearch(backend: str = "jnp", bsearch_interval: int = 5,
             bsearch_eps: float = 1e-3, **_: Any) -> ThresholdBSearch:
    return ThresholdBSearch(backend=backend, interval=bsearch_interval,
                            eps=bsearch_eps)


registry.register_alias(registry.COMPRESSOR, "threshold_binary_search",
                        "threshold_bsearch")


@registry.register(registry.COMPRESSOR, "quantized")
def _quantized(inner: _Base | None = None, **params: Any) -> Quantized:
    # bare "quantized" defaults to the exact-top-k inner selector
    return Quantized(inner if inner is not None
                     else registry.make(registry.COMPRESSOR, "exact_topk",
                                        **params))
