"""Compressor implementations (RedSync §5.2), registry-addressable.

Each compressor owns one leaf's selection semantics and its share of the
wire protocol (capacity + decompression); packing and collectives live in
``transport``. All implementations are stateless Python objects — JAX
state (threshold cache, quantization phase, bsearch refresh interval)
rides in the per-leaf ``LeafState``.

Registered names: ``dense``, ``exact_topk``, ``trimmed_topk``,
``threshold_bsearch`` (alias ``threshold_binary_search``),
``sampled_bsearch``, and the ``quantized(<inner>)`` wrapper. Factories
accept the shared parameter bag (``backend``, ``bsearch_interval``,
``sampled_tolerance``, ...) and ignore what they don't use, so
``registry.make(COMPRESSOR, name, **params)`` works uniformly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import registry
from . import selection as sel_lib
from . import sync as sync_lib
from .cost_model import sample_stride, sampled_capacity
from .residual import LeafState, init_leaf
from .selection import Selected


class _Base:
    """Shared init/decompress; subclasses define capacity + compress.

    Compressors with a segmented implementation (``supports_segmented``)
    additionally expose ``compress_segments``: Algorithm 2/3 over every
    slot of a flat residual arena at once (``repro.core.arena`` /
    ``repro.kernels.segmented``), bitwise identical to calling
    ``compress`` per leaf. Leaves whose compressor lacks one (exact_topk,
    quantized wrappers, custom compressors) simply stay on the per-leaf
    path when arenas are enabled.
    """

    name = "?"
    quantized = False
    supports_segmented = False

    def init_leaf(self, param: jax.Array, *, momentum: bool,
                  residual_dtype: Any = jnp.float32) -> LeafState:
        return init_leaf(param, momentum=momentum,
                         residual_dtype=residual_dtype)

    # --- segmented-arena protocol -------------------------------------
    # ``segment_spec`` describes this arena's selection to the fused
    # multi-arena driver (``kernels.segmented.multi_select``), so
    # GradientSync can run select for EVERY arena of a step in one
    # dispatch; ``finish_segments`` folds the returned per-segment
    # thresholds back into the slots' LeafStates.

    def segment_spec(self, geom, states):
        raise NotImplementedError(
            f"compressor {self.name!r} has no segmented implementation")

    def finish_segments(self, states, thr):
        return list(states)

    def compress_segments(self, x2d, geom, states, stats=None):
        """Single-arena convenience: ``multi_select`` over one part."""
        from repro.kernels import segmented as kseg
        spec = self.segment_spec(geom, states)
        ((sel, thr),) = kseg.multi_select(
            [(x2d, geom, spec, stats)],
            use_pallas=self.backend == "pallas")
        return sel, self.finish_segments(states, thr)

    def decompress(self, gathered: jax.Array, size: int, k: int) -> jax.Array:
        return sync_lib.unpack_decompress(
            gathered, size, self.capacity(k), self.quantized)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<compressor {self.name}>"


class Dense(_Base):
    """Sentinel: leaf takes the dense allreduce path (no sparse message).

    ``GradientSync`` routes "dense" leaves through
    ``Transport.allreduce_mean`` + plain momentum SGD; compress/decompress
    are never called.
    """

    name = "dense"

    def capacity(self, k: int) -> int:
        return 0

    def compress(self, flat_v, k, state):
        raise NotImplementedError(
            "dense leaves are synchronized via Transport.allreduce_mean")


class ExactTopK(_Base):
    """The radixSelect baseline: exact |x| top-k (capacity == k)."""

    name = "exact_topk"

    def capacity(self, k: int) -> int:
        return k

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        return sel_lib.exact_topk(flat_v, k), state

    def quant_select(self, flat_v: jax.Array, k: int,
                     phase: jax.Array) -> Selected:
        return sel_lib.exact_topk_quant(flat_v, k, phase)


class TrimmedTopK(_Base):
    """Alg 2: statistics-guided trimming, then top-k over survivors."""

    name = "trimmed_topk"
    supports_segmented = True

    def __init__(self, backend: str = "jnp", eps: float = 0.2):
        self.backend = backend
        self.eps = eps

    def capacity(self, k: int) -> int:
        return k

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            return kops.trimmed_topk(flat_v, k), state
        return sel_lib.trimmed_topk(flat_v, k, self.eps), state

    def segment_spec(self, geom, states):
        """Alg 2 spec; mirrors ``compress`` per backend (the pallas
        per-leaf path uses the kernel-default eps)."""
        from repro.kernels import segmented as kseg
        return kseg.SegmentSpec(
            alg="trimmed",
            eps=0.2 if self.backend == "pallas" else self.eps)

    def quant_select(self, flat_v: jax.Array, k: int,
                     phase: jax.Array) -> Selected:
        return sel_lib.trimmed_topk_quant(flat_v, k, phase, self.eps)


class ThresholdBSearch(_Base):
    """Alg 3: sampled threshold binary search with threshold reuse.

    capacity == 2k (padded; true length in the ``count`` header). The
    binary search refreshes every ``interval`` iterations and reuses the
    cached ``LeafState.threshold`` in between (§5.2.2 "sampled" variant).
    """

    name = "threshold_bsearch"
    supports_segmented = True

    def __init__(self, backend: str = "jnp", interval: int = 5,
                 eps: float = 1e-3, warm_start: bool = True):
        self.backend = backend
        self.interval = interval
        self.eps = eps
        self.warm_start = warm_start

    def capacity(self, k: int) -> int:
        return 2 * k

    def _warm(self, state: LeafState) -> jax.Array | None:
        return state.threshold if self.warm_start else None

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        if self.backend == "pallas":
            from repro.kernels import ops as kops

            def refresh(_):
                s, thr = kops.threshold_binary_search(
                    flat_v, k, eps=self.eps, warm=self._warm(state))
                return s, thr

            def reuse(_):
                s = kops.threshold_filter(flat_v, state.threshold, 2 * k)
                return s, state.threshold
        else:
            def refresh(_):
                s, thr = sel_lib.threshold_binary_search(
                    flat_v, k, self.eps, warm=self._warm(state))
                return s, thr

            def reuse(_):
                s = sel_lib.threshold_filter(flat_v, state.threshold,
                                             capacity=2 * k)
                return s, state.threshold

        do_refresh = (state.interval % self.interval) == 0
        s, thr = jax.lax.cond(do_refresh, refresh, reuse, operand=None)
        return s, state._replace(threshold=thr,
                                 interval=state.interval + 1)

    def segment_spec(self, geom, states):
        """Alg 3 spec with §5.2.2 threshold reuse per segment from the
        cached LeafState scalars (both backends — the pallas reuse/warm
        logic lives in the segmented driver itself)."""
        from repro.kernels import segmented as kseg
        intervals = jnp.stack([st.interval for st in states])
        cached = jnp.stack([st.threshold for st in states])
        return kseg.SegmentSpec(alg="bsearch", eps=self.eps,
                                refresh=(intervals % self.interval) == 0,
                                cached=cached, warm=self.warm_start)

    def finish_segments(self, states, thr):
        return [st._replace(threshold=thr[i], interval=st.interval + 1)
                for i, st in enumerate(states)]

    def quant_select(self, flat_v: jax.Array, k: int,
                     phase: jax.Array) -> Selected:
        # threshold sharing is incompatible with the alternating sign
        # phase (§5.2.3), so the quantized variant always re-searches.
        return sel_lib.threshold_binary_search_quant(flat_v, k, phase,
                                                     self.eps)


class SampledBSearch(ThresholdBSearch):
    """Alg 3 with DGC-style sampled statistics and sampled nnz counting.

    Mean/max and every per-iteration ``nnz(|x| > t)`` are estimated from
    a strided ``[::stride]`` subsample (``cost_model.sample_stride``
    sizes the stride from ``tolerance``), cutting the bisection's
    count-launch traffic by ~``stride`` x. Because the scaled count
    ``nnz_sub * stride`` only estimates the true nnz, the message
    capacity carries tolerance headroom: ``capacity(k) ==
    2k + ceil(2k * tolerance)`` (``cost_model.sampled_capacity``); the
    final filter uses the TRUE count, with overflow pinned the same way
    as the exact selector. ``tolerance == 0`` degenerates to stride 1 ==
    the exact ``threshold_bsearch`` bitwise.
    """

    name = "sampled_bsearch"

    def __init__(self, backend: str = "jnp", interval: int = 5,
                 eps: float = 1e-3, warm_start: bool = True,
                 tolerance: float = 0.5):
        super().__init__(backend=backend, interval=interval, eps=eps,
                         warm_start=warm_start)
        self.tolerance = tolerance

    def capacity(self, k: int) -> int:
        return sampled_capacity(k, self.tolerance)

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        cap = self.capacity(k)
        stride = sample_stride(k, self.tolerance)
        if self.backend == "pallas":
            # the strided count/stats kernels exist only in segmented
            # form — view the lone leaf as a one-slot arena (bitwise the
            # per-leaf 2-D layout) and let the segmented driver handle
            # reuse/warm/sampling in one place.
            from repro.core.arena import ARENA_BLOCK, single_slot_geometry
            from repro.kernels import segmented as kseg
            from repro.kernels.ops import _to2d
            x2d, _ = _to2d(flat_v, ARENA_BLOCK)
            geom = single_slot_geometry(flat_v.size, k)
            sel, thr = kseg.threshold_bsearch_segments(
                x2d, geom, eps=self.eps, use_pallas=True,
                refresh=jnp.reshape((state.interval % self.interval) == 0,
                                    (1,)),
                cached=jnp.reshape(state.threshold, (1,)),
                warm=self.warm_start,
                strides=(stride,), capacities=(cap,))
            return sel[0], state._replace(threshold=thr[0],
                                          interval=state.interval + 1)

        def refresh(_):
            s, thr = sel_lib.sampled_threshold_search(
                flat_v, k, stride=stride, capacity=cap, eps=self.eps,
                warm=self._warm(state))
            return s, thr

        def reuse(_):
            s = sel_lib.threshold_filter(flat_v, state.threshold,
                                         capacity=cap)
            return s, state.threshold

        do_refresh = (state.interval % self.interval) == 0
        s, thr = jax.lax.cond(do_refresh, refresh, reuse, operand=None)
        return s, state._replace(threshold=thr,
                                 interval=state.interval + 1)

    def segment_spec(self, geom, states):
        spec = super().segment_spec(geom, states)
        return spec._replace(
            strides=tuple(sample_stride(k, self.tolerance)
                          for k in geom.seg_ks),
            capacities=tuple(self.capacity(k) for k in geom.seg_ks))

    # no quantized variant: the single-mean payload is incompatible with
    # the sampled capacity headroom (count header could exceed 2k).
    quant_select = None


class Quantized(_Base):
    """§5.2.3 wrapper: same-signed selection + single-scalar-mean payload.

    Alternates top-k (positives) and bottom-k (negatives) via
    ``LeafState.phase``; the wire message carries (count, indices, mean)
    — ``sync.pack``/``unpack_decompress`` handle the payload swap via the
    ``quantized`` flag.
    """

    quantized = True

    def __init__(self, inner: _Base):
        if getattr(inner, "quantized", False):
            raise ValueError("cannot quantize an already-quantized "
                             f"compressor {inner.name!r}")
        if not callable(getattr(inner, "quant_select", None)):
            raise ValueError(
                f"compressor {inner.name!r} has no quantized variant")
        self.inner = inner
        self.name = f"quantized({inner.name})"

    def capacity(self, k: int) -> int:
        return self.inner.capacity(k)

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        sel = self.inner.quant_select(flat_v, k, state.phase)
        return sel, state._replace(phase=(state.phase + 1) % 2)


# --- registration ----------------------------------------------------------

@registry.register(registry.COMPRESSOR, "dense")
def _dense(**_: Any) -> Dense:
    return Dense()


@registry.register(registry.COMPRESSOR, "exact_topk")
def _exact(**_: Any) -> ExactTopK:
    return ExactTopK()


@registry.register(registry.COMPRESSOR, "trimmed_topk")
def _trimmed(backend: str = "jnp", trim_eps: float = 0.2,
             **_: Any) -> TrimmedTopK:
    return TrimmedTopK(backend=backend, eps=trim_eps)


@registry.register(registry.COMPRESSOR, "threshold_bsearch")
def _bsearch(backend: str = "jnp", bsearch_interval: int = 5,
             bsearch_eps: float = 1e-3, warm_start: bool = True,
             **_: Any) -> ThresholdBSearch:
    return ThresholdBSearch(backend=backend, interval=bsearch_interval,
                            eps=bsearch_eps, warm_start=warm_start)


registry.register_alias(registry.COMPRESSOR, "threshold_binary_search",
                        "threshold_bsearch")


@registry.register(registry.COMPRESSOR, "sampled_bsearch")
def _sampled(backend: str = "jnp", bsearch_interval: int = 5,
             bsearch_eps: float = 1e-3, warm_start: bool = True,
             sampled_tolerance: float = 0.5, **_: Any) -> SampledBSearch:
    return SampledBSearch(backend=backend, interval=bsearch_interval,
                          eps=bsearch_eps, warm_start=warm_start,
                          tolerance=sampled_tolerance)


@registry.register(registry.COMPRESSOR, "quantized")
def _quantized(inner: _Base | None = None, **params: Any) -> Quantized:
    # bare "quantized" defaults to the exact-top-k inner selector
    return Quantized(inner if inner is not None
                     else registry.make(registry.COMPRESSOR, "exact_topk",
                                        **params))
