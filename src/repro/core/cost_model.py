"""RedSync communication cost model (§5.5, Appendix B).

    T_sparse = T_select + lg(p)·α + (p−1)·M·D·β + p·γ₁          (Eq 1)
    T_dense  = 2·lg(p)·α + 2·(p−1)/p·M·β + (p−1)/p·γ₂           (Eq 2)

α: per-message latency [s]; β: transfer time per element [s/elem]
(β = elem_bytes / link bandwidth); γ₁: per-node decompress cost for a
size-M message; γ₂: dense reduction cost for a size-M message.

The model drives two things:
  * ``choose_method`` — the paper's per-layer dispatch (<128 KB dense
    allreduce; 128 KB–4 MB trimmed top-k; >4 MB sampled binary search).
  * the Fig 7/8/9 scalability projections in benchmarks/.

Hardware presets include the paper's two testbeds and our TPU v5e target.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class NetworkModel:
    """α/β/γ parameters for one interconnect + accelerator pairing."""
    name: str
    alpha: float          # latency per message [s]
    bandwidth: float      # bytes/s effective allreduce/allgather bandwidth
    gamma1: float         # decompress (scatter-add) [s per message element]
    gamma2: float         # dense reduce [s per element]
    elem_bytes: int = 4   # f32 wire format

    @property
    def beta(self) -> float:
        return self.elem_bytes / self.bandwidth


# The paper's testbeds (§6.1: Muradin 3.5 GB/s, Piz Daint 1.5 GB/s) and the
# TPU v5e target (~50 GB/s/link ICI). γ values follow the paper's observation
# that decompression runs at a fraction of HBM bandwidth for small messages.
MURADIN = NetworkModel("muradin-8xTitanV", alpha=10e-6, bandwidth=3.5e9,
                       gamma1=2e-11, gamma2=5e-12)
PIZ_DAINT = NetworkModel("piz-daint-P100", alpha=15e-6, bandwidth=1.5e9,
                         gamma1=2e-11, gamma2=5e-12)
TPU_V5E = NetworkModel("tpu-v5e-ici", alpha=1e-6, bandwidth=50e9,
                       gamma1=5e-12, gamma2=1.2e-12)

PRESETS = {m.name: m for m in (MURADIN, PIZ_DAINT, TPU_V5E)}


def t_sparse(p: int, m: int, density: float, net: NetworkModel,
             t_select: float = 0.0, quantized: bool = False) -> float:
    """Eq 1. ``m`` in elements. Quantization halves the value payload
    (indices + one scalar instead of indices + values)."""
    payload = m * density * (1.0 if quantized else 2.0) / 2.0
    # payload above is in "index+value pairs" halves: full message is
    # k indices + k values (2k elems); quantized is k indices + 1 (~k elems).
    wire_elems = m * density * (1.0 if quantized else 2.0)
    del payload
    return (t_select
            + math.log2(max(p, 2)) * net.alpha
            + (p - 1) * wire_elems * net.beta
            + p * (m * density) * net.gamma1)


def t_dense(p: int, m: int, net: NetworkModel) -> float:
    """Eq 2 (Rabenseifner allreduce)."""
    return (2 * math.log2(max(p, 2)) * net.alpha
            + 2 * (p - 1) / p * m * net.beta
            + (p - 1) / p * m * net.gamma2)


def speedup(p: int, m: int, density: float, net: NetworkModel,
            t_select: float = 0.0, quantized: bool = False) -> float:
    return t_dense(p, m, net) / t_sparse(p, m, density, net, t_select, quantized)


def bandwidth_ratio(p: int, density: float) -> float:
    """Paper's §5.5 observation: sparse/dense *bandwidth-term* ratio is
    (p−1)·D / (2·(p−1)/p) = p·D/2 — model compression ≠ wire compression.
    With p=128, D=0.1% → 6.4% (12.8% for unquantized idx+val messages)."""
    return (p - 1) * density / (2 * (p - 1) / p)


# --- the paper's per-layer method dispatch (§5.5 last paragraph) -----------

DENSE_THRESHOLD_BYTES = 128 * 1024        # below: dense allreduce
TRIMMED_THRESHOLD_BYTES = 4 * 1024 * 1024  # below: trimmed top-k; above: bsearch


def choose_method(param_bytes: int,
                  dense_threshold: int = DENSE_THRESHOLD_BYTES,
                  trimmed_threshold: int = TRIMMED_THRESHOLD_BYTES) -> str:
    """§5.5 dispatch with PINNED half-open boundaries.

    ``[0, dense)`` → dense; ``[dense, trimmed)`` → trimmed top-k;
    ``[trimmed, ∞)`` → threshold binary search. The paper says "smaller
    than 128 KB", so a leaf of EXACTLY 128 KB is sparsified (trimmed) and
    one of exactly 4 MB goes to the binary search. 0-byte leaves are
    dense (nothing to select from; the dense collective is a no-op).
    ``dispatch.SizeBasedPolicy`` delegates here, so the cost model and the
    live per-leaf dispatch can never disagree at the boundaries.
    """
    if param_bytes < 0:
        raise ValueError(f"param_bytes must be >= 0, got {param_bytes}")
    if param_bytes < dense_threshold:
        return "dense"
    if param_bytes < trimmed_threshold:
        return "trimmed_topk"
    return "threshold_binary_search"
