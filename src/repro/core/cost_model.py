"""RedSync communication cost model (§5.5, Appendix B).

    T_sparse = T_select + lg(p)·α + (p−1)·M·D·β + p·γ₁          (Eq 1)
    T_dense  = 2·lg(p)·α + 2·(p−1)/p·M·β + (p−1)/p·γ₂           (Eq 2)

α: per-message latency [s]; β: transfer time per element [s/elem]
(β = elem_bytes / link bandwidth); γ₁: per-node decompress cost for a
size-M message; γ₂: dense reduction cost for a size-M message.

The model drives two things:
  * ``choose_method`` — the paper's per-layer dispatch (<128 KB dense
    allreduce; 128 KB–4 MB trimmed top-k; >4 MB sampled binary search).
  * the Fig 7/8/9 scalability projections in benchmarks/.

Hardware presets include the paper's two testbeds and our TPU v5e target.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class NetworkModel:
    """α/β/γ parameters for one interconnect + accelerator pairing."""
    name: str
    alpha: float          # latency per message [s]
    bandwidth: float      # bytes/s effective allreduce/allgather bandwidth
    gamma1: float         # decompress (scatter-add) [s per message element]
    gamma2: float         # dense reduce [s per element]
    elem_bytes: int = 4   # f32 wire format

    @property
    def beta(self) -> float:
        return self.elem_bytes / self.bandwidth


# The paper's testbeds (§6.1: Muradin 3.5 GB/s, Piz Daint 1.5 GB/s) and the
# TPU v5e target (~50 GB/s/link ICI). γ values follow the paper's observation
# that decompression runs at a fraction of HBM bandwidth for small messages.
MURADIN = NetworkModel("muradin-8xTitanV", alpha=10e-6, bandwidth=3.5e9,
                       gamma1=2e-11, gamma2=5e-12)
PIZ_DAINT = NetworkModel("piz-daint-P100", alpha=15e-6, bandwidth=1.5e9,
                         gamma1=2e-11, gamma2=5e-12)
TPU_V5E = NetworkModel("tpu-v5e-ici", alpha=1e-6, bandwidth=50e9,
                       gamma1=5e-12, gamma2=1.2e-12)

PRESETS = {m.name: m for m in (MURADIN, PIZ_DAINT, TPU_V5E)}


# Effective selection scan rate [elements/s]: trimmed top-k style single
# pass over the residual at a fraction of memory bandwidth (Fig 3 scale —
# a 27M-element ResNet50 selects in ~3 ms on the paper's GPUs).
SELECT_THROUGHPUT = 9e9


def t_select_model(m: int, throughput: float = SELECT_THROUGHPUT) -> float:
    """Modeled selection time for an ``m``-element residual (one scan)."""
    return m / throughput


def sample_stride(k: int, tolerance: float, block: int = 1024) -> int:
    """Subsampling stride for sampled threshold search (DGC-style).

    The sampled nnz estimate at the true in-band threshold has relative
    sampling error ~ sqrt(stride / k); keeping that within tolerance/2 of
    the k..2k band gives ``stride <= k * tolerance^2 / 4``. Rounded down
    to a power of two so the stride divides the arena block size and the
    per-leaf / segmented subsample grids coincide, and capped at
    ``block`` so every kernel row contributes at least one sample.
    ``tolerance <= 0`` pins the exact path (stride 1).
    """
    if tolerance <= 0.0 or k <= 0:
        return 1
    target = max(1.0, k * tolerance * tolerance / 4.0)
    stride = 1 << int(math.floor(math.log2(target)))
    return max(1, min(block, stride))


def sampled_capacity(k: int, tolerance: float) -> int:
    """Message capacity for sampled bsearch: 2k plus tolerance headroom.

    The sampled search can converge to a threshold whose *true* nnz
    overshoots the k..2k band by ~the sampling tolerance; the extra
    ``ceil(2k * tolerance)`` slots absorb that so the overflow flag fires
    only on genuine estimate blowouts. ``tolerance=0`` gives exactly the
    exact-path capacity ``2k``.
    """
    return 2 * k + int(math.ceil(2 * k * tolerance))


def t_select_sampled(m: int, density: float, tolerance: float,
                     search_iters: int = 10,
                     throughput: float = SELECT_THROUGHPUT) -> float:
    """Modeled sampled-selection time for an ``m``-element residual.

    The bisection's ``search_iters`` counting scans touch only the
    ``m / stride`` subsample; one full scan remains for the final filter
    that materializes the message. ``tolerance=0`` degenerates to the
    exact search cost (``search_iters`` full scans + the filter scan).
    """
    k = max(1, int(m * density))
    stride = sample_stride(k, tolerance)
    return (search_iters * (m / stride) + m) / throughput


def eq1_terms(p: int, m: int, density: float, net: NetworkModel,
              t_select: float = 0.0, quantized: bool = False) -> dict:
    """Eq 1 term-by-term: the ONE definition of the sparse-step costs.

    ``m`` in elements. The wire message is k indices + k values (2k
    elements); quantization replaces the values with one scalar mean, so
    the payload halves to ~k elements (§5.2.3). ``unpack`` is the p·γ₁
    decompression term that Fig 10 shows dominating at scale. Both the
    scalar ``t_sparse`` and the Fig 7/10 benchmark decompositions are
    sums/shares of exactly these terms.
    """
    wire_elems = m * density * (1.0 if quantized else 2.0)
    return {
        "select": t_select,
        "latency": math.log2(max(p, 2)) * net.alpha,
        "bandwidth": (p - 1) * wire_elems * net.beta,
        "unpack": p * (m * density) * net.gamma1,
    }


def t_sparse(p: int, m: int, density: float, net: NetworkModel,
             t_select: float = 0.0, quantized: bool = False) -> float:
    """Eq 1 (the sum of ``eq1_terms``)."""
    return sum(eq1_terms(p, m, density, net, t_select, quantized).values())


def t_dense(p: int, m: int, net: NetworkModel) -> float:
    """Eq 2 (Rabenseifner allreduce)."""
    return (2 * math.log2(max(p, 2)) * net.alpha
            + 2 * (p - 1) / p * m * net.beta
            + (p - 1) / p * m * net.gamma2)


def speedup(p: int, m: int, density: float, net: NetworkModel,
            t_select: float = 0.0, quantized: bool = False) -> float:
    return t_dense(p, m, net) / t_sparse(p, m, density, net, t_select, quantized)


def predicted_shares(p: int, m: int, density: float, net: NetworkModel,
                     t_select: float | None = None,
                     quantized: bool = False) -> dict:
    """Fig 10 modeled decomposition: share of step time per stage.

    ``t_select=None`` derives the selection time from ``t_select_model``
    (one residual scan) instead of a hard-coded constant. ``transfer``
    folds the latency and bandwidth terms together, matching how the
    measured pipeline times its single ``transfer`` stage.
    """
    if t_select is None:
        t_select = t_select_model(m)
    terms = eq1_terms(p, m, density, net, t_select, quantized)
    tot = sum(terms.values())
    return {
        "select": terms["select"] / tot,
        "transfer": (terms["latency"] + terms["bandwidth"]) / tot,
        "unpack": terms["unpack"] / tot,
        "total_s": tot,
    }


def bandwidth_ratio(p: int, density: float) -> float:
    """Paper's §5.5 observation: sparse/dense *bandwidth-term* ratio is
    (p−1)·D / (2·(p−1)/p) = p·D/2 — model compression ≠ wire compression.
    With p=128, D=0.1% → 6.4% (12.8% for unquantized idx+val messages)."""
    return (p - 1) * density / (2 * (p - 1) / p)


# --- the paper's per-layer method dispatch (§5.5 last paragraph) -----------

DENSE_THRESHOLD_BYTES = 128 * 1024        # below: dense allreduce
TRIMMED_THRESHOLD_BYTES = 4 * 1024 * 1024  # below: trimmed top-k; above: bsearch


def choose_method(param_bytes: int,
                  dense_threshold: int = DENSE_THRESHOLD_BYTES,
                  trimmed_threshold: int = TRIMMED_THRESHOLD_BYTES) -> str:
    """§5.5 dispatch with PINNED half-open boundaries.

    ``[0, dense)`` → dense; ``[dense, trimmed)`` → trimmed top-k;
    ``[trimmed, ∞)`` → threshold binary search. The paper says "smaller
    than 128 KB", so a leaf of EXACTLY 128 KB is sparsified (trimmed) and
    one of exactly 4 MB goes to the binary search. 0-byte leaves are
    dense (nothing to select from; the dense collective is a no-op).
    ``dispatch.SizeBasedPolicy`` delegates here, so the cost model and the
    live per-leaf dispatch can never disagree at the boundaries.
    """
    if param_bytes < 0:
        raise ValueError(f"param_bytes must be >= 0, got {param_bytes}")
    if param_bytes < dense_threshold:
        return "dense"
    if param_bytes < trimmed_threshold:
        return "trimmed_topk"
    return "threshold_binary_search"
