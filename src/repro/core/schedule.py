"""Warm-up density schedule (RedSync §5.7).

The paper's recommendation: exponentially decay density over the first
epochs — 25%, 6.25%, 1.5625%, 0.4%, then the target (0.1%). RedSync's own
improvement for large scale: replace the high-density warm-up stages with
plain dense-allreduce SGD (density 1.0 sentinel), because even 1.56% density
saturates the dense bandwidth at p=64 (§5.7).

Density is *static per compiled step* (message capacity is a trace-time
shape), so the trainer recompiles at stage boundaries — 5 compilations total.

Registry-addressable form: the ``warmup`` Correction
(``core.correction.Warmup``) wraps a ``DensitySchedule`` so a spec like
``"warmup+momentum+clip(threshold_bsearch)"`` carries the ramp with the
optimizer; ``GradientSync.scheduled_density`` / ``Trainer.density_at``
consult it ahead of the trainer-level schedule.
"""
from __future__ import annotations

from dataclasses import dataclass, field


DGC_WARMUP = (0.25, 0.0625, 0.015625, 0.004)


@dataclass(frozen=True)
class DensitySchedule:
    """Piecewise-constant density over training steps."""
    target: float = 0.001
    warmup_steps_per_stage: int = 0
    stages: tuple[float, ...] = DGC_WARMUP
    dense_warmup: bool = False   # RedSync large-scale variant (§5.7)

    def density_at(self, step: int) -> float:
        if self.warmup_steps_per_stage <= 0:
            return self.target
        stage = step // self.warmup_steps_per_stage
        if stage >= len(self.stages):
            return self.target
        if self.dense_warmup:
            return 1.0           # sentinel: use dense allreduce this stage
        return self.stages[stage]

    def boundaries(self) -> list[int]:
        if self.warmup_steps_per_stage <= 0:
            return []
        return [self.warmup_steps_per_stage * (i + 1)
                for i in range(len(self.stages))]
