"""Legacy RGC entry points — thin shims over the composable API.

The monolithic Algorithm 4 + 5 implementation that used to live here has
been decomposed into ``Compressor`` / ``Transport`` / ``GradientSync``
(see ``repro.core.api``); ``rgc_init`` / ``rgc_apply`` are kept for one
release as shims so existing callers keep working:

    cfg = RGCConfig(density=0.001, sync_axes=("data",))
    state = rgc_init(params, cfg)                  # == sync.init(params)
    new_p, new_s = rgc_apply(grads, params, state, lr=lr, cfg=cfg)

New code should build a ``GradientSync`` directly:

    from repro.core import build_gradient_sync
    sync = build_gradient_sync("rgc", sync_axes=("data",), density=0.001)
    state = sync.init(params)
    new_p, new_s = sync.update(grads, state, params, lr)

Semantics are bitwise-identical (tests/test_api.py proves it against a
frozen copy of the monolith) with one intentional fix: per-leaf §5.5
dispatch now uses real ``dtype.itemsize`` bytes instead of assuming
4 bytes/element, so bf16 models dispatch correctly across the
128 KB / 4 MB boundaries.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .cost_model import DENSE_THRESHOLD_BYTES, TRIMMED_THRESHOLD_BYTES
from .dispatch import SizeBasedPolicy, leaf_nbytes
from .gradient_sync import GradientSync, build_gradient_sync

# canonical registry name -> the method string the legacy API exposed
_LEGACY_METHOD = {
    "dense": "dense",
    "trimmed_topk": "trimmed_topk",
    "threshold_bsearch": "threshold_binary_search",
}


@dataclass(frozen=True)
class RGCConfig:
    density: float = 0.001
    momentum: float = 0.9
    nesterov: bool = False
    weight_decay: float = 0.0
    quantize: bool = False
    dense_threshold_bytes: int = DENSE_THRESHOLD_BYTES
    trimmed_threshold_bytes: int = TRIMMED_THRESHOLD_BYTES
    bsearch_interval: int = 5
    fuse_messages: bool = True
    local_clip: float | None = None
    sync_axes: tuple[str, ...] = ("data",)
    # memory adaptation for very large models (recorded in EXPERIMENTS.md):
    # bf16 residual halves RGC state; selection/wire stay f32
    residual_dtype: Any = jnp.float32
    # selection backend: "jnp" (XLA) or "pallas" (kernels/, interpret on CPU)
    backend: str = "jnp"
    # leaves whose path matches any of these substrings are never quantized
    # (paper: "we do not quantify the output layer", §5.2.3)
    no_quant_paths: tuple[str, ...] = ("lm_head", "embed")


def gradient_sync_from_rgc_config(cfg: RGCConfig) -> GradientSync:
    """The ``GradientSync`` equivalent of a legacy ``RGCConfig``."""
    return build_gradient_sync(
        "rgc_quant" if cfg.quantize else "rgc",
        transport=("fused_allgather" if cfg.fuse_messages
                   else "per_leaf_allgather"),
        sync_axes=cfg.sync_axes,
        density=cfg.density,
        momentum=cfg.momentum,
        nesterov=cfg.nesterov,
        weight_decay=cfg.weight_decay,
        local_clip=cfg.local_clip,
        residual_dtype=cfg.residual_dtype,
        no_quant_paths=cfg.no_quant_paths,
        dense_threshold_bytes=cfg.dense_threshold_bytes,
        trimmed_threshold_bytes=cfg.trimmed_threshold_bytes,
        backend=cfg.backend,
        bsearch_interval=cfg.bsearch_interval,
        # the legacy monolith cold-searched on every refresh; keep its
        # bitwise parity contract by disabling the warm-started bracket
        warm_start=False,
    )


def leaf_bytes(x: jax.Array) -> int:
    """Deprecated: real storage bytes of a leaf (use ``dispatch.leaf_nbytes``)."""
    return leaf_nbytes(x)


def leaf_method(x: jax.Array, cfg: RGCConfig) -> str:
    policy = SizeBasedPolicy(cfg.dense_threshold_bytes,
                             cfg.trimmed_threshold_bytes)
    return _LEGACY_METHOD[policy.compressor_for("", x)]


def rgc_init(params: Any, cfg: RGCConfig | None = None) -> Any:
    """State tree congruent with params (LeafState at each leaf)."""
    cfg = cfg or RGCConfig()
    return gradient_sync_from_rgc_config(cfg).init(params)


def rgc_apply(
    grads: Any,
    params: Any,
    state: Any,
    *,
    lr: jax.Array,
    cfg: RGCConfig,
    density: float | None = None,
) -> tuple[Any, Any]:
    """One synchronized RGC update. Returns (new_params, new_state).

    Must be called inside a fully-manual shard_map region whose axis names
    include ``cfg.sync_axes``.
    """
    sync = gradient_sync_from_rgc_config(cfg)
    return sync.update(grads, state, params, lr, density=density)
