"""Composable gradient-compression API (RedSync decomposed).

The paper's pipeline — residual accumulation → communication-set selection
→ packing → sparse allgather → decompression → apply — is decomposed into
four swappable protocols, each string-addressable via
``repro.core.registry``:

``Compressor``
    Per-leaf selection/decompression policy. ``compress`` maps a flat f32
    residual vector to a fixed-capacity ``Selected`` set (plus updated
    ``LeafState`` — threshold cache, quantization phase); ``decompress``
    turns gathered wire messages back into a dense f32 update sum.
    Implementations: ``dense``, ``exact_topk``, ``trimmed_topk`` (Alg 2),
    ``threshold_bsearch`` (Alg 3), and the ``quantized(inner)`` wrapper
    (§5.2.3).

``Transport``
    Wire packing + collectives over ``sync_axes``. Implementations:
    ``fused_allgather`` (§5.3 tensor fusion: one collective for all
    leaves), ``bucketed_allgather`` (§5.3 fusion under a fixed byte
    budget — one collective per bucket), ``hierarchical`` (§5.4
    intra-node dense psum + inter-node sparse allgather on a 2-axis
    mesh), ``per_leaf_allgather``, and ``dense_psum`` (dense baseline —
    sparse messages are a configuration error). Every transport carries a
    ``StageTimer`` hook for instrumentation-grade counters.

``StageTimer``
    Stage instrumentation hook threaded through ``GradientSync.update``
    and the transports: each pipeline stage (``accumulate`` / ``select``
    / ``mask`` / ``pack`` / ``transfer`` / ``unpack`` — Fig 10's
    decomposition with the paper's "mask" bar split) runs inside
    ``timer.stage(name, thunk)``; ``timer.count`` records
    ``dispatch_<stage>`` fused-operation launches and transport
    collective/message counts. ``repro.core.instrument`` ships
    ``NullTimer`` (free, trace-safe default) and ``WallClockTimer``
    (barriered wall-clock sampling for eager benchmark runs).

``DispatchPolicy``
    Chooses a compressor *name* per leaf. ``size_based`` is the paper's
    §5.5 byte-size dispatch (using real ``dtype.itemsize`` bytes);
    ``fixed`` routes every leaf through one named compressor.

``Schedule``
    The §5.6 overlap scheduler: owns the ORDER in which
    ``GradientSync.update`` compresses, dispatches and applies its sync
    units, and any cross-step double buffering. ``sequential`` is the
    historical full-tree barrier; ``chunked`` partitions the tree into
    reverse-parameter-order chunks and dispatches each chunk's
    collective as soon as its gradients are processed (bitwise
    identical results, >= 2 transport dispatches per step); ``stale1``
    communicates step *t-1*'s compressed residual during step *t*
    (double-buffered, one step of sparse staleness). Implementations in
    ``repro.core.overlap``.

``Correction``
    Convergence-preserving transforms (Deep Gradient Compression, Lin et
    al. 1712.01887) that run AHEAD of any registered compressor:
    gradient pre-transforms (``local_clip``), residual accumulation
    ownership (``momentum`` correction), post-selection state masking
    (``factor_masking``), and the density warm-up ramp (``warmup``).
    Implementations in ``repro.core.correction``; composed via the
    extended spec grammar, e.g. ``"momentum+clip(threshold_bsearch)"``.

``GradientSync`` (repro.core.gradient_sync) composes the four into an
optax-style ``init(params)`` / ``update(grads, state, params, lr)``
transform; ``rgc_apply`` is now a thin shim over it.

These are structural ``Protocol``s: implementations register with the
registry and need not inherit anything.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax

from .residual import LeafState
from .selection import Selected


@runtime_checkable
class Compressor(Protocol):
    """Per-leaf compression: residual vector -> sparse communication set.

    Compressors MAY additionally set ``supports_segmented = True`` and
    provide ``compress_segments(arena2d, geometry, states, stats)`` —
    the same selection over every slot of a flat residual arena at once
    (``repro.core.arena``); ``GradientSync`` fuses only leaves whose
    compressor does, and falls back per leaf otherwise.
    """

    name: str
    quantized: bool      # wire payload is (count, indices, mean) if True

    def capacity(self, k: int) -> int:
        """Fixed message capacity (trace-time shape) for a target of k."""
        ...

    def init_leaf(self, param: jax.Array, *, momentum: bool,
                  residual_dtype: Any) -> LeafState:
        """Per-leaf residual/momentum/threshold state."""
        ...

    def compress(self, flat_v: jax.Array, k: int,
                 state: LeafState) -> tuple[Selected, LeafState]:
        """Select the communication set from the flat f32 residual."""
        ...

    def decompress(self, gathered: jax.Array, size: int,
                   k: int) -> jax.Array:
        """[workers, msg_len] wire messages -> dense f32[size] update SUM."""
        ...


@runtime_checkable
class StageTimer(Protocol):
    """Pipeline stage instrumentation (Fig 10's mask/select/pack/transfer/
    unpack decomposition). ``stage`` executes and may time a stage body;
    ``count`` records dimensionless facts (collectives per step, bucket
    counts). Implementations: ``instrument.NullTimer`` (default; ``stage``
    is a bare passthrough, safe under tracing) and
    ``instrument.WallClockTimer`` (eager-mode barriered timing)."""

    active: bool

    def stage(self, name: str, thunk: Any) -> Any:
        """Run ``thunk()`` as pipeline stage ``name``; return its value."""
        ...

    def count(self, name: str, n: int = 1) -> None:
        """Accumulate a counter (no barrier, no timing)."""
        ...

    def set_lane(self, lane: str | None) -> None:
        """Attribute subsequent stages to a lane (e.g. ``"chunk0"`` —
        the per-chunk attribution of the ``chunked`` schedule); ``None``
        returns to the unlaned default."""
        ...

    def summary(self) -> dict:
        """Collected per-stage timings/counters ({} for null timers)."""
        ...


@runtime_checkable
class Transport(Protocol):
    """Wire packing + collectives over the data-parallel mesh axes."""

    name: str
    sync_axes: tuple[str, ...]
    timer: Any            # StageTimer hook (NullTimer when unset)

    def num_workers(self) -> int:
        """Product of ``sync_axes`` sizes (1 outside any mesh)."""
        ...

    def pack(self, sel: Selected, quantized: bool) -> jax.Array:
        """Selected -> packed f32 wire message."""
        ...

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        """Exchange packed messages; returns per-leaf [workers, len]."""
        ...

    def allreduce_mean(self, grad: jax.Array) -> jax.Array:
        """Dense fallback for small leaves (psum / pmean)."""
        ...


@runtime_checkable
class DispatchPolicy(Protocol):
    """Per-leaf compressor choice (the §5.5 method dispatch, pluggable).

    ``compressor_for`` receives the RAW gradient leaf (pre-correction, so
    byte-size dispatch sees the parameter's true storage dtype) and must
    depend only on its path/shape/dtype — the decision is cached per
    (treedef, leaf signature, density) and leaves are tracers under jit,
    so value-dependent dispatch was never expressible anyway.
    """

    def compressor_for(self, path: str, leaf: jax.Array) -> str:
        """Registered compressor name for this leaf ("dense" = allreduce)."""
        ...


@runtime_checkable
class Correction(Protocol):
    """Convergence correction run ahead of any compressor (DGC lineage).

    ``GradientSync.update`` folds every configured correction through four
    hooks, in pipeline order: ``on_grads`` (tree-level gradient transform,
    pre-accumulation), ``accumulate`` (optional ownership of a leaf's
    residual update — first correction returning non-None wins; None means
    "not mine" and core falls back to plain ``V += g``),
    ``on_communicated`` (state masking after selection; the residual is
    already cleared), and ``density_at`` (the warm-up schedule; None means
    "no schedule owned here"). ``repro.core.correction.CorrectionBase``
    provides no-op defaults for all four.
    """

    name: str
    needs_momentum_buffer: bool   # allocate param-shaped LeafState.momentum

    def on_grads(self, grads: list[jax.Array], params: list[jax.Array],
                 num_workers: int) -> list[jax.Array]:
        """Transform the whole local gradient list before accumulation."""
        ...

    def accumulate(self, grad: jax.Array, param: jax.Array,
                   state: LeafState, *,
                   weight_decay: float) -> LeafState | None:
        """Fold this leaf's gradient into its residual; None = pass."""
        ...

    def on_communicated(self, state: LeafState,
                        indices: jax.Array) -> LeafState:
        """Mask leaf state at communicated coordinates (padding-safe)."""
        ...

    def density_at(self, step: int, target: float) -> float | None:
        """Scheduled density at ``step``; None = no schedule owned here."""
        ...


@runtime_checkable
class Schedule(Protocol):
    """Overlap scheduler (§5.6): the dispatch order of one sync step.

    ``GradientSync`` delegates its whole ``init``/``update`` orchestration
    here: ``init_state`` may wrap the params-congruent LeafState tree
    (``stale1`` adds the double-buffered pending messages —
    ``overlap.ScheduleState``), and ``step`` drives the pipeline through
    the ``GradientSync`` stage helpers (``_compress_plan`` / ``_gather``
    / ``_apply_gathered`` / ``_dense_reduce`` / ``_dense_apply``),
    deciding how the work is chunked, when each transport collective is
    dispatched, and which step's messages it carries. Implementations:
    ``repro.core.overlap`` (``sequential`` / ``chunked`` / ``stale1``),
    registry kind ``registry.SCHEDULE``.
    """

    name: str

    def init_state(self, sync: Any, params: Any, leaf_state: Any) -> Any:
        """Wrap (or pass through) the LeafState tree as the full state."""
        ...

    def step(self, sync: Any, grads: Any, state: Any, params: Any,
             lr: jax.Array, density: float) -> tuple[Any, Any]:
        """One synchronized step; returns (new_params, new_state)."""
        ...

    def wrap_state_specs(self, leaf_specs: Any, replicated: Any) -> Any:
        """Partition specs congruent with ``init_state``'s wrapping:
        given the LeafState tree's specs and a replicated (prefix) spec
        for any schedule-owned buffers, return the full state's specs
        (the trainer's shard_map/jit plumbing)."""
        ...
