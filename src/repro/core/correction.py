"""Correction implementations (DGC, Lin et al. 1712.01887), registry-addressable.

RedSync's accuracy story rests on residual accumulation, but Deep Gradient
Compression showed that four auxiliary techniques are what keep aggressively
sparsified training at dense-equivalent convergence. Each is a ``Correction``
(see ``repro.core.api``) that ``GradientSync.update`` runs AHEAD of whatever
compressor the dispatch policy picks:

* ``momentum``       — momentum correction: accumulate a local velocity U and
                       add U (not g) into the residual V (Alg 4 l.11–19).
                       Includes DGC momentum factor masking of its own
                       velocity buffer at communicated coordinates, so
                       ``"momentum+…"`` alone is convergence-safe.
* ``factor_masking`` — standalone momentum factor masking (alias
                       ``masking``): clear U at communicated coordinates.
                       For pipelines that manage velocity some other way;
                       redundant (and harmless) next to ``momentum``.
* ``local_clip``     — DGC local gradient clipping (alias ``clip``): scale
                       the whole local gradient so its norm stays under
                       N^{-1/2} of the global clip threshold, *before*
                       residual accumulation.
* ``warmup``         — the §5.7 sparsity ramp: exposes a
                       ``core.schedule.DensitySchedule`` through
                       ``density_at`` so the trainer ramps density (or runs
                       RedSync's dense warm-up) before the target sparsity.

Corrections compose with compressors through the extended ``TrainConfig``
spec grammar::

    "momentum+clip(threshold_bsearch)"      # corrections wrap a compressor
    "momentum+clip+threshold_bsearch"       # equivalent flat form
    "warmup(rgc)"                           # corrections around §5.5 dispatch
    "momentum"                              # base defaults to "rgc"

``split_corrections`` parses a spec into (correction names, base optimizer
spec); the base spec is whatever ``build_gradient_sync`` already accepted
(``rgc`` / ``rgc_quant`` / ``dense`` / any registered compressor spec).

Factories receive the shared parameter bag (``momentum``, ``nesterov``,
``local_clip``, ``density``, ``warmup_steps_per_stage``, ...) and ignore
what they don't use, so ``registry.make(CORRECTION, name, **params)`` works
uniformly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import registry
from .residual import (LeafState, accumulate, local_clip_scale,
                       mask_momentum, pinned_product)
from .schedule import DGC_WARMUP, DensitySchedule


class CorrectionBase:
    """No-op defaults for every ``Correction`` hook.

    Subclasses override the hooks they need; ``GradientSync.update`` folds
    all registered corrections through each hook in pipeline order.
    """

    name = "?"
    # True if this correction reads/writes the param-shaped velocity buffer
    # (LeafState.momentum); GradientSync allocates it when any correction
    # (or the dense-leaf momentum SGD) needs it.
    needs_momentum_buffer = False
    # True if this correction's on_communicated is momentum factor
    # masking: on the fused arena path the core clears the coalesced
    # velocity arena once instead of folding per-leaf hooks.
    arena_mask_momentum = False

    def arena_coeffs(self) -> tuple[float, bool] | None:
        """(momentum, nesterov) if this correction owns residual
        accumulation in the fusable Alg 4 form; None = not an owner."""
        return None

    def arena_safe(self) -> bool:
        """Whether the flat-arena fast path reproduces this correction
        exactly. The default is structural: a correction that overrides
        neither per-leaf hook is trivially safe; the built-ins that DO
        override them (momentum, factor_masking) declare their arena
        form via ``arena_coeffs`` / ``arena_mask_momentum`` and override
        this to True. Custom corrections with bespoke per-leaf hooks
        return False and ``GradientSync`` silently falls back to the
        per-leaf path — correctness first, fusion second.
        """
        cls = type(self)
        return (cls.accumulate is CorrectionBase.accumulate
                and cls.on_communicated is CorrectionBase.on_communicated)

    def on_grads(self, grads: list[jax.Array], params: list[jax.Array],
                 num_workers: int) -> list[jax.Array]:
        """Tree-level gradient transform before residual accumulation."""
        return grads

    def accumulate(self, grad: jax.Array, param: jax.Array,
                   state: LeafState, *,
                   weight_decay: float) -> LeafState | None:
        """Own this leaf's residual accumulation; None = not this correction.

        The first correction returning a state wins; with none,
        ``GradientSync`` does the plain ``V += g`` accumulation.
        """
        return None

    def on_communicated(self, state: LeafState,
                        indices: jax.Array) -> LeafState:
        """Post-selection state masking (residual is already cleared)."""
        return state

    def density_at(self, step: int, target: float) -> float | None:
        """Scheduled density for this step; None = no schedule owned here."""
        return None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<correction {self.name}>"


class MomentumCorrection(CorrectionBase):
    """DGC momentum correction on the residual buffer (Alg 4 l.11–19).

    U ← m·U + g locally; V ← V + U (plus g again under Nesterov). Both the
    residual (cleared by core) and this velocity are cleared at communicated
    coordinates — the velocity clear IS momentum factor masking, owned here
    because stale velocity re-adding communicated mass is the known DGC
    divergence mode; ``"momentum"`` without ``"factor_masking"`` stays safe.
    """

    name = "momentum"
    needs_momentum_buffer = True
    arena_mask_momentum = True

    def __init__(self, momentum: float = 0.9, nesterov: bool = False):
        self.momentum = momentum
        self.nesterov = nesterov

    def accumulate(self, grad, param, state, *, weight_decay):
        return accumulate(grad, param, state, momentum=self.momentum,
                          nesterov=self.nesterov, weight_decay=weight_decay)

    def on_communicated(self, state, indices):
        return mask_momentum(state, indices)

    def arena_coeffs(self):
        return self.momentum, self.nesterov

    def arena_safe(self):
        return True


class FactorMasking(CorrectionBase):
    """Standalone DGC momentum factor masking: clear U at communicated
    coordinates. No-op when the leaf carries no param-shaped velocity."""

    name = "factor_masking"
    arena_mask_momentum = True

    def on_communicated(self, state, indices):
        return mask_momentum(state, indices)

    def arena_safe(self):
        return True


class LocalClip(CorrectionBase):
    """DGC local gradient clipping (§5.6): scale the LOCAL gradient so its
    norm stays under N^{-1/2} of the global clip threshold, before the
    residual accumulates it."""

    name = "local_clip"

    def __init__(self, clip_norm: float = 1.0):
        self.clip_norm = clip_norm

    def on_grads(self, grads, params, num_workers):
        # order-pinned: the squared-norm reduction and the scaled
        # gradient feed the residual adds of every leaf, so a
        # graph-shape-dependent partial-sum order or fma(g, scale, .)
        # contraction would break per-leaf <-> arena bitwise parity
        from .selection import pinned_sum
        sq = sum(pinned_sum(g.astype(jnp.float32) ** 2) for g in grads)
        scale = local_clip_scale(sq, self.clip_norm, num_workers)
        return [pinned_product(g, scale) for g in grads]


class Warmup(CorrectionBase):
    """Sparsity warm-up ramp (§5.7), driving ``core.schedule``.

    Wraps a ``DensitySchedule``; the trainer asks
    ``GradientSync.scheduled_density(step)`` which folds through this hook.
    Density is static per compiled step, so the ramp manifests as the
    trainer recompiling at stage boundaries — this correction owns *what*
    the density is, not *when* jit retraces.
    """

    name = "warmup"
    DEFAULT_STEPS_PER_STAGE = 25

    def __init__(self, schedule: DensitySchedule):
        self.schedule = schedule

    def density_at(self, step, target):
        return self.schedule.density_at(step)


# --- spec grammar ----------------------------------------------------------

def _split_top_plus(spec: str) -> tuple[str, str | None]:
    """First top-level '+'-separated term, and the remainder (or None)."""
    depth = 0
    for i, ch in enumerate(spec):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif ch == "+" and depth == 0:
            return spec[:i].strip(), spec[i + 1:].strip()
    return spec.strip(), None


def _is_correction(name: str) -> bool:
    return name in registry.names(registry.CORRECTION)


def split_corrections(spec: str) -> tuple[list[str], str]:
    """Parse the extended optimizer grammar into (corrections, base spec).

    ``"momentum+clip(threshold_bsearch)"`` → (["momentum", "clip"],
    "threshold_bsearch"); a correction term may carry the rest of the
    pipeline in parens (``"warmup(rgc)"``) or continue with ``+``; the base
    (non-correction) term must come last and defaults to ``""`` when the
    spec is corrections-only.
    """
    corrections: list[str] = []
    rest = spec.strip()
    while rest:
        term, tail = _split_top_plus(rest)
        head, _, paren = term.partition("(")
        head = head.strip()
        if paren and term.endswith(")") and _is_correction(head):
            if tail is not None:
                raise ValueError(
                    f"bad optimizer spec {spec!r}: parenthesized correction "
                    f"{head!r} must wrap the rest of the pipeline")
            corrections.append(head)
            rest = paren[:-1].strip()
            continue
        if _is_correction(term):
            corrections.append(term)
            rest = tail or ""
            continue
        if tail is not None:
            raise ValueError(
                f"bad optimizer spec {spec!r}: {term!r} is not a registered "
                f"correction {registry.names(registry.CORRECTION)} and only "
                f"the final term may name the base optimizer")
        return corrections, term
    return corrections, ""


# --- registration ----------------------------------------------------------

@registry.register(registry.CORRECTION, "momentum")
def _momentum(momentum: float = 0.9, nesterov: bool = False,
              **_: Any) -> MomentumCorrection:
    return MomentumCorrection(momentum=momentum, nesterov=nesterov)


@registry.register(registry.CORRECTION, "factor_masking")
def _factor_masking(**_: Any) -> FactorMasking:
    return FactorMasking()


@registry.register(registry.CORRECTION, "local_clip")
def _local_clip(local_clip: float | None = None, **_: Any) -> LocalClip:
    return LocalClip(clip_norm=1.0 if local_clip is None else local_clip)


@registry.register(registry.CORRECTION, "warmup")
def _warmup(density: float = 0.001, warmup_steps_per_stage: int = 0,
            dense_warmup: bool = False,
            warmup_stages: tuple[float, ...] = DGC_WARMUP,
            **_: Any) -> Warmup:
    # a spec that *names* warmup asks for an actual ramp: fall back to a
    # default stage length when the config leaves it unset
    steps = (warmup_steps_per_stage if warmup_steps_per_stage > 0
             else Warmup.DEFAULT_STEPS_PER_STAGE)
    return Warmup(DensitySchedule(target=density,
                                  warmup_steps_per_stage=steps,
                                  stages=tuple(warmup_stages),
                                  dense_warmup=dense_warmup))


registry.register_alias(registry.CORRECTION, "clip", "local_clip")
registry.register_alias(registry.CORRECTION, "masking", "factor_masking")
