"""``GradientSync``: the composed RedSync pipeline (Algorithms 4 + 5).

Optax-style transform built from three registry-addressable pieces:

    sync = build_gradient_sync(optimizer="rgc", sync_axes=("data",), ...)
    state = sync.init(params)
    new_params, new_state = sync.update(grads, state, params, lr)

``update`` runs the paper's six stages per step — DGC local clipping →
residual/momentum accumulation → selection (``Compressor``) → packing +
sparse allgather (``Transport``) → scatter-add decompression → SGD apply
— with the per-leaf method choice owned by a ``DispatchPolicy``.
``density >= 1.0`` is the §5.7 dense-warm-up sentinel: every leaf takes
the dense allreduce path regardless of policy.

With ``fuse_leaves`` (default) the sparse path runs over FLAT RESIDUAL
ARENAS (``repro.core.arena``): leaves sharing a gradient dtype and a
segmented compressor coalesce into contiguous f32 arenas; the mask /
pack stages each issue ONE fused operation per arena instead of one per
leaf, and select goes further — ALL arenas of a step search together in
one ``kernels.segmented.multi_select`` (a single count launch per
search iteration for every segment of every arena) — O(arenas) -> O(1)
dispatches for the Fig 10 overhead stages — while selection stays
segmented per leaf, so the communicated set, params and optimizer state
are bitwise identical to the per-leaf path. The static per-step plan (paths, dispatch, k targets, arena
layout) is cached per (treedef, leaf signature, density).

The ORDER of one step's dispatches is owned by a ``Schedule``
(``repro.core.overlap``, ``TrainConfig.schedule``): ``sequential`` is
the historical compress-all → one-transfer → apply barrier; ``chunked``
partitions the tree into reverse-parameter-order chunks (§5.6 — the
order backprop emits gradients) and dispatches each chunk's collective
as soon as that chunk is packed, bitwise identical to sequential;
``stale1`` double-buffers the packed messages and communicates step
*t-1*'s buffer during step *t*.

Like the legacy ``rgc_apply`` it replaces (now a shim over this), it must
run inside a fully-manual shard_map region whose axis names include the
transport's ``sync_axes``; every leaf is a raw local shard and gradients
are local (un-averaged).

``optimizer`` accepts ``"rgc"`` (§5.5 size-based dispatch), ``"rgc_quant"``
(same + §5.2.3 quantization), ``"dense"``, or ANY registered compressor
spec — e.g. ``"threshold_bsearch"`` or ``"quantized(trimmed_topk)"`` —
which routes every leaf through that compressor. The spec may additionally
prefix ``+``-joined DGC ``Correction`` names that run ahead of whatever
compressor dispatch picks: ``"momentum+clip(threshold_bsearch)"`` is
momentum correction → local clipping → Alg 3 selection on every leaf, and
``"warmup(rgc)"`` ramps density over the §5.5 dispatch (see
``repro.core.correction``). Spec corrections are additive: the
``momentum`` / ``local_clip`` config fields stay the on/off switches for
their corrections whether or not the spec names them, so legacy specs and
``rgc_apply`` keep bitwise parity and ``"warmup(rgc)"`` is exactly
``"rgc"`` plus the ramp.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import arena
from . import registry
from .api import Compressor, Correction, DispatchPolicy, Transport
from .compressors import _Base as _CompressorBase  # noqa: F401 (registration)
from .correction import LocalClip, MomentumCorrection, split_corrections
from .dispatch import FixedPolicy, SizeBasedPolicy, leaf_nbytes
from .instrument import NullTimer
from .overlap import SequentialSchedule, partition_chunks
from .residual import (LeafState, accumulate, accumulate_arena,
                       mask_communicated)
from .sync import message_len
from .transport import DEFAULT_BUCKET_BYTES
from .transport import FusedAllgather  # noqa: F401 (registration)


class _StepPlan(NamedTuple):
    """Static per-step dispatch plan, cached per (treedef, leaf signature,
    density, all_dense) — paths, compressor choices, k targets and the
    arena layout never change within a trace, so they are computed once
    instead of per update call."""

    paths: tuple[str, ...]
    dense: tuple[int, ...]                              # dense-path leaves
    sparse: tuple[tuple[int, Any, int], ...]            # per-leaf (i, comp, k)
    groups: tuple[arena.ArenaGroup, ...]                # fused arenas
    group_comps: tuple[Any, ...]                        # compressor per group


def _by_leaf(group: arena.ArenaGroup, states: list,
             fld: str) -> dict[int, Any]:
    """Leaf-indexed view of per-slot state fields (what ``arena.gather``
    consumes)."""
    return {slot.leaf: getattr(st, fld)
            for slot, st in zip(group.slots, states)}


@dataclass
class GradientSync:
    """Composed residual-gradient-compression transform."""

    policy: DispatchPolicy
    transport: Transport
    density: float = 0.001
    momentum: float = 0.9
    nesterov: bool = False
    weight_decay: float = 0.0
    local_clip: float | None = None
    quantize: bool = False
    no_quant_paths: tuple[str, ...] = ("lm_head", "embed")
    residual_dtype: Any = jnp.float32
    # Flat residual arenas: coalesce same-dtype sparse leaves that share a
    # segmented compressor into contiguous f32 arenas, so mask / pack run
    # once per ARENA and select once per STEP (all arenas fused into one
    # multi_select; see repro.core.arena). Selection
    # stays segmented per leaf — the communicated set, params and state
    # are bitwise identical to the per-leaf path. Leaves without a
    # segmented compressor (exact_topk, quantized) and pipelines with
    # non-arena-safe custom corrections fall back per leaf automatically.
    fuse_leaves: bool = True
    # Also run residual accumulation as ONE fused pass per arena (the
    # single-launch residual-update+stats kernel of kernels/segmented.py)
    # instead of per leaf. Off by default: the momentum / weight-decay
    # products may differ from the per-leaf graph by <= 1 ulp when XLA
    # FMA-contracts one side, so this trades bitwise reproducibility vs
    # the per-leaf path for one fewer HBM round-trip (exact when
    # momentum == weight_decay == 0).
    fuse_accumulate: bool = False
    # DGC corrections run ahead of any compressor, in order. Spec-named
    # corrections land here explicitly; the momentum / local_clip config
    # fields ALWAYS imply their corrections (those fields are the on/off
    # switches — legacy semantics), appended unless the same name was
    # already given, so e.g. "warmup(rgc)" keeps momentum correction on
    # sparse leaves consistent with the dense-leaf momentum SGD.
    corrections: tuple[Correction, ...] | None = None
    # parameter bag threaded to compressor factories (backend,
    # bsearch_interval, trim_eps, ...)
    compressor_params: dict = field(default_factory=dict)
    # §5.6 overlap scheduler (core.overlap): owns the dispatch order of
    # the step — "sequential" full-tree barrier (default), "chunked"
    # per-chunk pipelined dispatch, "stale1" one-step-delayed double
    # buffering. None -> SequentialSchedule.
    schedule: Any = None
    # byte budget of one "chunked" pipeline chunk (raw gradient bytes;
    # shares the bucket_bytes knob/default with the bucketed transport)
    chunk_bytes: int = DEFAULT_BUCKET_BYTES
    # stage-timer hook (core.instrument): NullTimer (free, trace-safe) by
    # default; bench_transport swaps in a WallClockTimer for eager runs
    timer: Any = None
    _compressors: dict = field(default_factory=dict, repr=False)
    _plans: dict = field(default_factory=dict, repr=False)

    def __post_init__(self) -> None:
        if self.timer is None:
            self.timer = NullTimer()
        if self.schedule is None:
            self.schedule = SequentialSchedule()
        corr = list(self.corrections or ())
        names = {c.name for c in corr}
        if self.local_clip is not None and "local_clip" not in names:
            corr.insert(0, LocalClip(self.local_clip))
        if self.momentum and "momentum" not in names:
            corr.append(MomentumCorrection(self.momentum, self.nesterov))
        self.corrections = tuple(corr)
        # arenas only fuse pipelines whose corrections they reproduce
        # exactly; a custom correction with bespoke per-leaf hooks drops
        # the whole pipeline back to the per-leaf path (never silently
        # changes results)
        self._arena_ok = all(
            getattr(c, "arena_safe", lambda: False)()
            for c in self.corrections)

    # -- construction helpers ----------------------------------------------

    def compressor(self, name: str) -> Compressor:
        """Resolve (and cache) a compressor instance by registered name."""
        if name not in self._compressors:
            self._compressors[name] = registry.make(
                registry.COMPRESSOR, name, **self.compressor_params)
        return self._compressors[name]

    def _leaf_compressor(self, name: str, path: str) -> Compressor:
        """Apply the §5.2.3 quantization wrap where configured.

        The output/embedding layers are never quantized ("we do not
        quantify the output layer").
        """
        if (self.quantize and name != "dense"
                and not name.startswith("quantized")
                and not any(t in path for t in self.no_quant_paths)):
            return self.compressor(f"quantized({name})")
        return self.compressor(name)

    @property
    def uses_momentum_buffer(self) -> bool:
        """Whether leaf states carry a param-shaped velocity (vs scalar)."""
        return bool(self.momentum) or any(
            getattr(c, "needs_momentum_buffer", False)
            for c in self.corrections)

    def scheduled_density(self, step: int) -> float | None:
        """Warm-up density at ``step`` from a schedule-owning correction
        (``warmup``); None when no correction owns a schedule."""
        for c in self.corrections:
            d = c.density_at(step, self.density)
            if d is not None:
                return d
        return None

    def _accumulate(self, grad: jax.Array, param: jax.Array,
                    state: LeafState) -> LeafState:
        """Residual accumulation: first owning correction wins, else V += g."""
        for c in self.corrections:
            st = c.accumulate(grad, param, state,
                              weight_decay=self.weight_decay)
            if st is not None:
                return st
        return accumulate(grad, param, state, momentum=0.0, nesterov=False,
                          weight_decay=self.weight_decay)

    # -- the transform ------------------------------------------------------

    def init(self, params: Any) -> Any:
        """Optimizer state for ``params``.

        The base is a params-congruent tree of ``LeafState`` — each
        leaf's state comes from the compressor the policy assigns it
        (all built-ins share ``residual.init_leaf``; custom compressors
        may carry extra state). The schedule may wrap it: ``stale1``
        returns an ``overlap.ScheduleState`` carrying the zero-count
        pending message buffers alongside the leaf tree.
        """
        leaves, treedef = jax.tree.flatten(params)
        paths = [jax.tree_util.keystr(kp) for kp, _ in
                 jax.tree_util.tree_flatten_with_path(params)[0]]
        out = []
        for path, p in zip(paths, leaves):
            name = self.policy.compressor_for(path, p)
            comp = self._leaf_compressor(name, path)
            out.append(comp.init_leaf(p, momentum=self.uses_momentum_buffer,
                                      residual_dtype=self.residual_dtype))
        leaf_state = jax.tree.unflatten(treedef, out)
        return self.schedule.init_state(self, params, leaf_state)

    # -- the per-step plan (cached; satellite of the arena refactor) --------

    def _plan(self, grads: Any, treedef: Any, leaves_g: list,
              density: float, all_dense: bool) -> _StepPlan:
        """Resolve (and cache) the static dispatch plan for this step.

        Paths, per-leaf compressor choices, ``k`` targets and the arena
        layout depend only on the tree structure, leaf shapes/dtypes and
        the density — all static per trace — so ``keystr`` /
        ``compressor_for`` / ``ceil`` run once per (treedef, signature,
        density, all_dense) instead of on every call.
        """
        sig = tuple((tuple(g.shape), str(g.dtype)) for g in leaves_g)
        key = (treedef, sig, density, all_dense)
        if key in self._plans:
            return self._plans[key]

        paths = [jax.tree_util.keystr(kp) for kp, _ in
                 jax.tree_util.tree_flatten_with_path(grads)[0]]
        plan = self._plan_leaves(range(len(leaves_g)), paths, leaves_g,
                                 density, all_dense)
        self._plans[key] = plan
        return plan

    def _plan_leaves(self, indices, paths, leaves_g, density: float,
                     all_dense: bool, aid_base: int = 0) -> _StepPlan:
        """Dispatch plan restricted to the leaves in ``indices`` (in the
        given order) — the whole tree for the sequential plan, one
        chunk's leaves for the chunked schedule's per-chunk plans.
        Arena grouping happens WITHIN the index set, so a chunk's fused
        operations touch only that chunk's leaves."""
        dense: list[int] = []
        sparse: list[tuple[int, Compressor, int]] = []
        fusable: dict[tuple[str, str], list] = {}
        for i in indices:
            g = leaves_g[i]
            name = ("dense" if all_dense
                    else self.policy.compressor_for(paths[i], g))
            if name == "dense":
                dense.append(i)
                continue
            k = max(1, int(math.ceil(density * g.size)))
            comp = self._leaf_compressor(name, paths[i])
            if (self.fuse_leaves and self._arena_ok
                    and getattr(comp, "supports_segmented", False)
                    and not comp.quantized):
                cap = comp.capacity(k)
                fusable.setdefault((comp.name, str(g.dtype)), []).append(
                    (i, paths[i], int(g.size), k, cap,
                     message_len(cap, False)))
            else:
                sparse.append((i, comp, k))

        groups, group_comps = [], []
        for aid, ((name, dtype), slots) in enumerate(fusable.items()):
            groups.append(arena.build_group(aid_base + aid, name, dtype,
                                            slots))
            group_comps.append(self.compressor(name))

        return _StepPlan(paths=tuple(paths[i] for i in indices),
                         dense=tuple(dense), sparse=tuple(sparse),
                         groups=tuple(groups),
                         group_comps=tuple(group_comps))

    def _chunk_plans(self, grads: Any, treedef: Any, leaves_g: list,
                     density: float,
                     all_dense: bool) -> tuple[_StepPlan, ...]:
        """Per-chunk dispatch plans for the ``chunked`` schedule (cached).

        ``overlap.partition_chunks`` splits the leaf set into
        reverse-parameter-order chunks under ``chunk_bytes`` (raw
        gradient bytes); each chunk then gets its own ``_plan_leaves``
        plan, so arenas never span a chunk boundary and every chunk's
        select/mask/pack feeds its own transport dispatch."""
        sig = tuple((tuple(g.shape), str(g.dtype)) for g in leaves_g)
        key = (treedef, sig, density, all_dense, "chunked",
               self.chunk_bytes)
        if key in self._plans:
            return self._plans[key]

        paths = [jax.tree_util.keystr(kp) for kp, _ in
                 jax.tree_util.tree_flatten_with_path(grads)[0]]
        chunks = partition_chunks([leaf_nbytes(g) for g in leaves_g],
                                  self.chunk_bytes)
        plans = tuple(
            self._plan_leaves(c.leaves, paths, leaves_g, density,
                              all_dense, aid_base=1000 * c.cid)
            for c in chunks)
        self._plans[key] = plans
        return plans

    def _pending_zeros(self, params: Any) -> tuple[jax.Array, ...]:
        """Zero-count wire messages matching the target-density plan's
        unit order (arena groups, then per-leaf sparse units) — the
        ``stale1`` schedule's initial double buffer. An all-zeros f32
        message decodes as count == 0, so applying it is a no-op."""
        leaves, treedef = jax.tree.flatten(params)
        plan = self._plan(params, treedef, leaves, self.density,
                          self.density >= 1.0)
        pending = [jnp.zeros((g.msg_total,), jnp.float32)
                   for g in plan.groups]
        pending += [jnp.zeros((message_len(comp.capacity(k),
                                           comp.quantized),), jnp.float32)
                    for _, comp, k in plan.sparse]
        return tuple(pending)

    def _arena_coeffs(self) -> tuple[float, bool]:
        """(momentum, nesterov) of the accumulation-owning correction —
        mirrors ``_accumulate``'s first-owner-wins rule for arenas."""
        for c in self.corrections:
            coeffs = getattr(c, "arena_coeffs", lambda: None)()
            if coeffs is not None:
                return coeffs
        return 0.0, False

    def _accumulate_group(self, group: arena.ArenaGroup, comp: Compressor,
                          leaves_g: list, leaves_p: list, leaves_s: list
                          ) -> tuple:
        """The accumulate phase of one fused arena step: residual update
        -> gather into the arena's 2-D view. Returns
        ``(v2d, u2d, stats, states_in)`` for the fused select phase.

        Residual accumulation defaults to the per-leaf hook chain
        (``_accumulate``) — its momentum product is the one piece of
        float arithmetic whose XLA FMA-contraction decision depends on
        the surrounding graph, so keeping the exact per-leaf subgraph is
        what makes the fused path BITWISE identical under jit. With
        ``fuse_accumulate`` the arena instead runs the single-pass fused
        residual-update+stats kernel (one HBM round-trip, O(arenas)
        dispatches) whose momentum product may differ from the per-leaf
        graph by <= 1 ulp when XLA contracts one side to an FMA — exact
        when ``momentum == 0`` and ``weight_decay == 0``.
        """
        timer = self.timer
        geom = group.geometry
        m, nesterov = self._arena_coeffs()
        use_pallas = getattr(comp, "backend", "jnp") == "pallas"
        mask_u = any(getattr(c, "arena_mask_momentum", False)
                     for c in self.corrections)
        need_u = self.uses_momentum_buffer and bool(m or mask_u)
        rd = (None if self.residual_dtype == jnp.float32
              else self.residual_dtype)

        if self.fuse_accumulate:
            def _acc():
                g2d = arena.gather(group, leaves_g)
                v2d = arena.gather(group, [s.residual for s in leaves_s])
                u2d = (arena.gather(group,
                                    [s.momentum for s in leaves_s])
                       if need_u else None)
                p2d = (arena.gather(group, leaves_p)
                       if self.weight_decay else None)
                if use_pallas:
                    from repro.kernels import segmented as kseg
                    v2, u2, sums, maxs = kseg.seg_residual_update_stats(
                        g2d, v2d, u2d if m else None, p2d, geom.block_seg,
                        geom.n_seg, momentum=m, nesterov=nesterov,
                        weight_decay=self.weight_decay, round_dtype=rd)
                    stats = (kseg.seg_mean(sums, geom), maxs)
                else:
                    v2, u2 = accumulate_arena(
                        g2d, v2d, u2d if m else None, p2d, momentum=m,
                        nesterov=nesterov, weight_decay=self.weight_decay,
                        residual_dtype=self.residual_dtype)
                    stats = None
                states = [leaves_s[slot.leaf] for slot in group.slots]
                return v2, (u2 if u2 is not None else u2d), stats, states

            timer.count("dispatch_accumulate")
            v2d, u2d, stats, states_in = timer.stage("accumulate", _acc)
        else:
            def _acc():
                states = []
                for slot in group.slots:
                    timer.count("dispatch_accumulate")
                    states.append(self._accumulate(
                        leaves_g[slot.leaf], leaves_p[slot.leaf],
                        leaves_s[slot.leaf]))
                v2d = arena.gather(group, _by_leaf(group, states,
                                                   "residual"))
                u2d = (arena.gather(group, _by_leaf(group, states,
                                                    "momentum"))
                       if need_u else None)
                return v2d, u2d, None, states

            v2d, u2d, stats, states_in = timer.stage("accumulate", _acc)

        return v2d, u2d, stats, states_in

    def _select_groups(self, groups, comps, accs) -> list[tuple]:
        """The fused select phase: Alg 2/3 across EVERY arena of the step
        in one ``multi_select`` call — a single count/compact dispatch
        per search iteration for all segments of all arenas (mixed
        backends partition into one call per backend). Returns one
        ``(selected, slot_states)`` pair per group.

        Compressors that predate the ``segment_spec`` protocol (custom
        subclasses overriding only ``compress_segments``) fall back to
        their own per-group call, preserving behavior at per-arena
        dispatch granularity.
        """
        from repro.kernels import segmented as kseg
        results: list[tuple | None] = [None] * len(groups)
        by_backend: dict[bool, list[int]] = {}
        for i, (group, comp) in enumerate(zip(groups, comps)):
            v2d, _u2d, stats, states_in = accs[i]
            try:
                spec = comp.segment_spec(group.geometry, states_in)
            except NotImplementedError:
                sel, slot_states = comp.compress_segments(
                    v2d, group.geometry, states_in, stats)
                results[i] = (sel, slot_states)
                continue
            by_backend.setdefault(
                getattr(comp, "backend", "jnp") == "pallas", []).append(
                    (i, spec))
        for use_pallas, entries in by_backend.items():
            parts = [(accs[i][0], groups[i].geometry, spec, accs[i][2])
                     for i, spec in entries]
            out = kseg.multi_select(parts, use_pallas=use_pallas)
            for (i, _spec), (sel, thr) in zip(entries, out):
                results[i] = (sel, comps[i].finish_segments(accs[i][3], thr))
        return results

    def _finish_group(self, group: arena.ArenaGroup, comp: Compressor,
                      selected: list, slot_states: list, v2d: jax.Array,
                      u2d: jax.Array | None, leaves_p: list,
                      new_states: list) -> jax.Array:
        """The post-select phase of one fused arena step: mask -> scatter
        state back -> pack; returns the packed arena message. The mask /
        pack stages each issue ONE fused operation for the whole arena.
        """
        timer = self.timer
        m, _ = self._arena_coeffs()
        mask_u = any(getattr(c, "arena_mask_momentum", False)
                     for c in self.corrections)
        need_u = self.uses_momentum_buffer and bool(m or mask_u)

        def _mask():
            gidx = arena.communicated_indices(group, selected)
            v = arena.mask_arena(v2d, gidx)
            u = (arena.mask_arena(u2d, gidx)
                 if (mask_u and need_u) else u2d)
            return v, u

        timer.count("dispatch_mask")
        v2d_m, u2d_m = timer.stage("mask", _mask)

        v_views = arena.scatter(group, v2d_m)
        u_views = arena.scatter(group, u2d_m) if need_u else {}
        for slot, st in zip(group.slots, slot_states):
            shape = leaves_p[slot.leaf].shape
            st = st._replace(residual=v_views[slot.leaf].reshape(shape)
                             .astype(self.residual_dtype))
            if need_u:
                st = st._replace(momentum=u_views[slot.leaf].reshape(shape))
            new_states[slot.leaf] = st

        timer.count("dispatch_pack")
        return timer.stage("pack",
                           lambda: arena.pack_group(group, selected))

    def _count_overflow(self, selections) -> None:
        """Surface ``threshold_filter`` capacity overflows (§pinned
        semantics: first-``capacity`` lowest-index survivors kept, count
        saturated) on the stage timer. Eager-only — under jit the flags
        are tracers and the counter stays silent (NullTimer is free)."""
        if not getattr(self.timer, "active", False):
            return
        for sel in selections:
            ovf = getattr(sel, "overflow", None)
            if ovf is not None and not isinstance(ovf, jax.core.Tracer):
                self.timer.count("select_overflow", int(bool(ovf)))

    def update(self, grads: Any, state: Any, params: Any, lr: jax.Array,
               *, density: float | None = None) -> tuple[Any, Any]:
        """One synchronized step. Returns (new_params, new_state).

        The dispatch ORDER is owned by the configured ``Schedule``
        (``core.overlap``): ``sequential`` runs compress-all → one
        transfer → apply (the historical order, reproduced below by the
        stage helpers it calls), ``chunked`` pipelines per-chunk
        compress+transfer dispatches, ``stale1`` communicates the
        previous step's buffer.
        """
        density = self.density if density is None else density
        return self.schedule.step(self, grads, state, params, lr, density)

    # -- schedule stage helpers (the pipeline's unit operations) ------------

    def _context(self, grads: Any, leaf_state: Any, params: Any):
        """Flatten the step's trees and run tree-level corrections (e.g.
        DGC local clipping — its N^{-1/2} norm is GLOBAL over the whole
        gradient tree, so it must run before any chunking).

        Returns BOTH the raw and the corrected gradient leaves: plans
        (``_plan`` / ``_chunk_plans`` / ``_pending_zeros``) must be built
        from the RAW leaves so §5.5 byte-size dispatch keeps seeing the
        parameter's true storage dtype (a correction like local_clip
        upcasts bf16 leaves to f32 — the mis-dispatch PR 1/PR 4 pinned
        out), while the compute stages consume the corrected leaves.
        """
        leaves_raw, treedef = jax.tree.flatten(grads)
        leaves_p = treedef.flatten_up_to(params)
        leaves_s = treedef.flatten_up_to(leaf_state)
        n_workers = self.transport.num_workers()
        leaves_g = list(leaves_raw)
        for c in self.corrections:
            leaves_g = c.on_grads(leaves_g, leaves_p, n_workers)
        return treedef, leaves_raw, leaves_g, leaves_p, leaves_s, n_workers

    def _compress_plan(self, plan: _StepPlan, leaves_g: list,
                       leaves_p: list, leaves_s: list, new_states: list
                       ) -> tuple[list[jax.Array], list[tuple]]:
        """Residual update + selection + message packing for every sparse
        unit of ``plan`` (arena groups first, then per-leaf fallbacks).

        Each stage body routes through the StageTimer hook
        (core.instrument): a free passthrough under jit/NullTimer, a
        barriered wall-clock sample per stage when bench_transport runs
        the pipeline eagerly (the measured Fig 10 decomposition).
        ``dispatch_<stage>`` counters record fused-operation launches:
        one per leaf in the fallback loop, one per arena for mask/pack —
        and ONE per step for select: all arenas' segments search
        together in a single ``multi_select`` (one count launch per
        iteration for everything). Returns ``(messages, msg_meta)``;
        mutates ``new_states`` in place.
        """
        timer = self.timer
        messages: list[jax.Array] = []
        msg_meta: list[tuple] = []

        if plan.groups:
            accs = [self._accumulate_group(group, comp, leaves_g,
                                           leaves_p, leaves_s)
                    for group, comp in zip(plan.groups, plan.group_comps)]
            timer.count("dispatch_select")
            results = timer.stage(
                "select", lambda: self._select_groups(
                    plan.groups, plan.group_comps, accs))
            self._count_overflow(
                s for sel, _ in results for s in sel)
            for (group, comp), (sel, slot_states), acc in zip(
                    zip(plan.groups, plan.group_comps), results, accs):
                messages.append(self._finish_group(
                    group, comp, sel, slot_states, acc[0], acc[1],
                    leaves_p, new_states))
                msg_meta.append(("arena", group, comp))

        for i, comp, k in plan.sparse:
            timer.count("dispatch_accumulate")
            st = timer.stage("accumulate", lambda i=i: self._accumulate(
                leaves_g[i], leaves_p[i], leaves_s[i]))
            flat_v = st.residual.reshape(-1).astype(jnp.float32)
            timer.count("dispatch_select")
            selected, st = timer.stage(
                "select", lambda f=flat_v, st=st: comp.compress(f, k, st))
            self._count_overflow([selected])

            def _mask(st=st, sel=selected):
                st2 = mask_communicated(st, sel.indices, momentum=False)
                for c in self.corrections:
                    st2 = c.on_communicated(st2, sel.indices)
                return st2
            timer.count("dispatch_mask")
            new_states[i] = timer.stage("mask", _mask)
            timer.count("dispatch_pack")
            messages.append(timer.stage(
                "pack",
                lambda sel=selected: self.transport.pack(sel, comp.quantized)))
            msg_meta.append(("leaf", i, comp, k))

        return messages, msg_meta

    def _gather(self, messages: list[jax.Array]) -> list[jax.Array]:
        """Dispatch the transport collective for one message batch."""
        return self.timer.stage(
            "transfer", lambda: self.transport.allgather(messages))

    def _apply_gathered(self, gathered: list[jax.Array],
                        msg_meta: list[tuple], leaves_p: list,
                        new_params: list, lr: jax.Array,
                        n_workers: int) -> None:
        """Decompress gathered messages and apply the SGD update (mutates
        ``new_params`` in place)."""
        timer = self.timer

        def _apply(buf, i, comp, k):
            g_sum = comp.decompress(buf, leaves_p[i].size, k)
            upd = (g_sum / n_workers).reshape(leaves_p[i].shape)
            return (leaves_p[i].astype(jnp.float32)
                    - lr * upd).astype(leaves_p[i].dtype)

        for buf, meta in zip(gathered, msg_meta):
            if meta[0] == "arena":
                _, group, comp = meta
                slot_bufs = arena.split_message(group, buf)
                for slot, sbuf in zip(group.slots, slot_bufs):
                    new_params[slot.leaf] = timer.stage(
                        "unpack", lambda b=sbuf, s=slot: _apply(
                            b, s.leaf, comp, s.k))
            else:
                _, i, comp, k = meta
                new_params[i] = timer.stage(
                    "unpack", lambda b=buf, i=i, c=comp, k=k: _apply(
                        b, i, c, k))

    def _dense_reduce(self, i: int, leaves_g: list) -> jax.Array:
        """Dispatch one dense leaf's allreduce-mean collective."""
        return self.timer.stage(
            "transfer",
            lambda: self.transport.allreduce_mean(leaves_g[i]))

    def _dense_apply(self, i: int, g_mean: jax.Array, leaves_p: list,
                     leaves_s: list, new_states: list, new_params: list,
                     lr: jax.Array) -> None:
        """Momentum-SGD apply of one dense leaf's reduced gradient."""
        st = leaves_s[i]
        if self.weight_decay:
            g_mean = g_mean + self.weight_decay * \
                leaves_p[i].astype(jnp.float32)
        if self.momentum:
            u = self.momentum * st.momentum + g_mean
            upd = (g_mean + self.momentum * u) if self.nesterov else u
            new_states[i] = st._replace(momentum=u)
        else:
            upd = g_mean
        new_params[i] = (leaves_p[i].astype(jnp.float32)
                         - lr * upd).astype(leaves_p[i].dtype)


def build_gradient_sync(
    optimizer: str = "rgc",
    *,
    transport: str = "fused_allgather",
    sync_axes: tuple[str, ...] = (),
    density: float = 0.001,
    momentum: float = 0.9,
    nesterov: bool = False,
    weight_decay: float = 0.0,
    local_clip: float | None = None,
    residual_dtype: Any = jnp.float32,
    no_quant_paths: tuple[str, ...] = ("lm_head", "embed"),
    dense_threshold_bytes: int | None = None,
    trimmed_threshold_bytes: int | None = None,
    warmup_steps_per_stage: int = 0,
    dense_warmup: bool = False,
    bucket_bytes: int | None = None,
    intra_axis: str | None = None,
    fuse_leaves: bool = True,
    fuse_accumulate: bool = False,
    schedule: str = "sequential",
    timer: Any = None,
    **compressor_params: Any,
) -> GradientSync:
    """Build a ``GradientSync`` from string-addressable component names.

    ``optimizer`` may prefix ``+``-joined correction names (see
    ``repro.core.correction``) ahead of a base spec, e.g.
    ``"momentum+clip(threshold_bsearch)"`` or ``"warmup(rgc)"``; a
    corrections-only spec defaults the base to ``"rgc"``. Base resolution:
      * ``"rgc"`` / ``"rgc_quant"`` — the paper's size-based dispatch
        (quantized variant wraps each non-dense compressor per §5.2.3);
      * ``"dense"`` — every leaf dense allreduce (baseline);
      * any registered compressor spec — fixed dispatch through it.

    Spec-named corrections are ADDITIVE: the ``momentum`` / ``local_clip``
    config fields remain the on/off switches for their corrections (legacy
    semantics — so ``"warmup(rgc)"`` keeps momentum correction exactly as
    ``"rgc"`` had it), and naming a correction already implied by a field
    just fixes its position in the pipeline. Ablate by zeroing the field,
    not by omitting the name.

    Transport knobs: ``bucket_bytes`` (bucketed_allgather's per-collective
    byte budget) and ``intra_axis`` (hierarchical's intra-node mesh axis;
    default = last sync axis) are forwarded to the transport factory;
    factories ignore knobs they don't consume. ``timer`` is the
    ``StageTimer`` hook shared by the sync loop and the transport
    (``None`` -> ``NullTimer``).

    ``schedule`` names the §5.6 overlap scheduler (``core.overlap``,
    registry kind ``SCHEDULE``): ``"sequential"`` (default, full-tree
    barrier), ``"chunked"`` (reverse-parameter-order chunk pipelining,
    bitwise identical results, chunk byte budget = ``bucket_bytes``), or
    ``"stale1"`` (one-step-delayed double-buffered sync; its state wraps
    the LeafState tree in an ``overlap.ScheduleState``).

    ``fuse_leaves`` (default on) enables the flat residual arenas: the
    select/mask/pack stages run once per same-dtype arena instead of once
    per leaf, bitwise identical to the per-leaf path
    (``repro.core.arena``). ``fuse_accumulate`` additionally fuses
    residual accumulation into one arena pass (the single-launch
    residual-update+stats kernel) at the cost of possible <= 1 ulp
    momentum-product drift vs the per-leaf graph (XLA FMA contraction).
    ``compressor_params`` may carry ``backend`` ("jnp" | "pallas") for
    the selection kernels; the Pallas backend auto-detects
    compiled-vs-interpreted per platform.
    """
    corr_names, base = split_corrections(optimizer)
    optimizer = base or "rgc"
    corrections: tuple[Correction, ...] | None = None
    if corr_names:
        corrections = tuple(
            registry.make(registry.CORRECTION, name,
                          momentum=momentum, nesterov=nesterov,
                          local_clip=local_clip, density=density,
                          warmup_steps_per_stage=warmup_steps_per_stage,
                          dense_warmup=dense_warmup, **compressor_params)
            for name in corr_names)

    policy_kw = {}
    if dense_threshold_bytes is not None:
        policy_kw["dense_threshold_bytes"] = dense_threshold_bytes
    if trimmed_threshold_bytes is not None:
        policy_kw["trimmed_threshold_bytes"] = trimmed_threshold_bytes

    quantize = False
    if optimizer in ("rgc", "rgc_quant"):
        policy: DispatchPolicy = registry.make(
            registry.DISPATCH_POLICY, "size_based", **policy_kw)
        quantize = optimizer == "rgc_quant"
    elif optimizer == "dense":
        policy = FixedPolicy("dense")
    elif registry.contains(registry.COMPRESSOR, optimizer):
        # fail at build time, not at the first jitted step, for specs that
        # parse but cannot be constructed (e.g. "quantized(dense)")
        registry.make(registry.COMPRESSOR, optimizer, **compressor_params)
        policy = FixedPolicy(optimizer)
    else:
        raise ValueError(
            f"unknown optimizer {optimizer!r}: expected rgc | rgc_quant | "
            f"dense | a registered compressor "
            f"{registry.names(registry.COMPRESSOR)}, optionally prefixed "
            f"by '+'-joined corrections "
            f"{registry.names(registry.CORRECTION)}")

    timer = timer if timer is not None else NullTimer()
    transport_kw: dict[str, Any] = {"sync_axes": tuple(sync_axes),
                                    "timer": timer}
    if bucket_bytes is not None:
        transport_kw["bucket_bytes"] = bucket_bytes
    if intra_axis is not None:
        transport_kw["intra_axis"] = intra_axis

    return GradientSync(
        policy=policy,
        transport=registry.make(registry.TRANSPORT, transport,
                                **transport_kw),
        density=density,
        momentum=momentum,
        nesterov=nesterov,
        weight_decay=weight_decay,
        local_clip=local_clip,
        quantize=quantize,
        no_quant_paths=tuple(no_quant_paths),
        residual_dtype=residual_dtype,
        fuse_leaves=fuse_leaves,
        fuse_accumulate=fuse_accumulate,
        schedule=registry.make(registry.SCHEDULE, schedule),
        chunk_bytes=(DEFAULT_BUCKET_BYTES if bucket_bytes is None
                     else int(bucket_bytes)),
        corrections=corrections,
        compressor_params=dict(compressor_params),
        timer=timer,
    )
