"""Stage instrumentation for the sync pipeline (Fig 10's decomposition).

``GradientSync.update`` and the transports thread every pipeline stage
through a ``StageTimer`` hook so the paper's Fig 10 time decomposition
can be measured on the REAL pipeline instead of an artificial loop. The
stage set refines Fig 10's by one split: the paper's ``mask`` bar merges
residual/momentum accumulation with post-selection state masking — two
different memory passes — so we time them separately as ``accumulate``
(Alg 4 l.8-19: weight decay + momentum correction + residual add) and
``mask`` (Alg 4 l.21-23: clearing V/U at communicated coordinates).
Summing the two recovers the paper's ``mask`` bar. The rest match Fig
10: ``select`` (communication-set selection), ``pack`` (wire-format
packing), ``transfer`` (the collectives, sparse and dense), ``unpack``
(scatter-add decompression + parameter apply).

Alongside wall time, ``GradientSync`` counts ``dispatch_<stage>`` —
fused-operation launches per stage (one per LEAF on the per-leaf path,
one per ARENA with ``fuse_leaves``) — and the transports count
``collectives`` / ``messages``; these are the O(leaves) → O(arenas)
facts ``benchmarks/bench_transport.py`` asserts on.

Two implementations:

* ``NullTimer`` — the default everywhere. ``stage`` just calls the thunk;
  safe (and free) under ``jit``/``shard_map`` tracing.
* ``WallClockTimer`` — wraps each stage with a ``jax.block_until_ready``
  barrier and accumulates wall time per stage. Only meaningful for EAGER
  (op-by-op) execution: under ``jit`` the thunk runs once at trace time
  and the barrier is a no-op on tracers, so times would be trace times.
  ``benchmarks/bench_transport.py`` runs the pipeline eagerly with this
  timer and emits ``BENCH_transport.json``.

Counters (``count``) record dimensionless stage facts — e.g. the
bucketed transport's collective count per step — without a barrier.

``set_lane`` opens a named attribution lane: while a lane is set, stage
times are ADDITIONALLY accumulated under it (``summary()["lanes"]``).
The ``chunked`` overlap schedule (core.overlap) sets one lane per
pipeline chunk, giving the per-chunk Fig 10 decomposition
``benchmarks/bench_transport.py``'s ``measured_overlap`` section
reports.
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Any, Callable

import jax

# Canonical stage order of one sync step (Fig 10's x-axis, with the
# paper's "mask" bar split into accumulate + mask — sum them to compare
# against Fig 10 directly). Pinned by tests/test_transport.py.
STAGES = ("accumulate", "select", "mask", "pack", "transfer", "unpack")


class NullTimer:
    """No-op timer: zero overhead, trace-safe. The default hook."""

    active = False

    def stage(self, name: str, thunk: Callable[[], Any]) -> Any:
        return thunk()

    def count(self, name: str, n: int = 1) -> None:
        pass

    def set_lane(self, lane: str | None) -> None:
        pass

    def summary(self) -> dict:
        return {}


class WallClockTimer:
    """Per-stage wall-clock accumulator with device barriers (eager only)."""

    active = True

    def __init__(self) -> None:
        self.times: dict[str, list[float]] = defaultdict(list)
        self.counts: dict[str, int] = defaultdict(int)
        # per-lane stage attribution (the chunked schedule's per-chunk
        # lanes): {lane: {stage: total_s}} accumulated alongside the
        # unlaned totals above
        self.lane_times: dict[str, dict[str, float]] = defaultdict(
            lambda: defaultdict(float))
        self._lane: str | None = None

    def stage(self, name: str, thunk: Callable[[], Any]) -> Any:
        t0 = time.perf_counter()
        out = thunk()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        self.times[name].append(dt)
        if self._lane is not None:
            self.lane_times[self._lane][name] += dt
        return out

    def count(self, name: str, n: int = 1) -> None:
        # coerce: callers may pass numpy/DeviceArray bools (e.g. the
        # select_overflow flag) — keep the counter a python int
        self.counts[name] += int(n)

    def set_lane(self, lane: str | None) -> None:
        self._lane = lane

    def reset(self) -> None:
        self.times.clear()
        self.counts.clear()
        self.lane_times.clear()
        self._lane = None

    def summary(self) -> dict:
        """Per-stage totals/means plus the share of the summed stage time.

        ``{"stages": {name: {calls, total_s, mean_ms, share}},
           "counts": {...}, "total_s": float}``; stage order follows
        ``STAGES`` with any custom stage names appended. When lanes were
        set (``set_lane``), a ``"lanes"`` key additionally maps each
        lane to its per-stage second totals.
        """
        totals = {n: sum(ts) for n, ts in self.times.items()}
        grand = sum(totals.values())
        order = [s for s in STAGES if s in totals] + sorted(
            n for n in totals if n not in STAGES)
        stages = {}
        for n in order:
            ts = self.times[n]
            stages[n] = {
                "calls": len(ts),
                "total_s": totals[n],
                "mean_ms": 1e3 * totals[n] / max(len(ts), 1),
                "share": totals[n] / grand if grand > 0 else 0.0,
            }
        out = {"stages": stages, "counts": dict(self.counts),
               "total_s": grand}
        if self.lane_times:
            out["lanes"] = {lane: dict(stages_)
                            for lane, stages_ in self.lane_times.items()}
        return out
