"""Transport backends: wire packing + collectives over ``sync_axes``.

Five registered backends (§5.3/§5.4):

* ``fused_allgather``   — tensor fusion: concatenate every leaf message
                          into ONE buffer, a single allgather, then split
                          (§5.3 "batch small allgather operations").
* ``bucketed_allgather`` — tensor fusion with a byte budget: messages are
                          greedily packed into contiguous fixed-byte
                          buckets (``bucket_bytes``) and each bucket runs
                          one fused allgather. Bounds the collective
                          buffer (no single giant concat) while still
                          amortizing launch latency — the §5.3 trade-off
                          made tunable. Delivers byte-identical gathered
                          rows to ``fused_allgather``.
* ``hierarchical``      — §5.4 two-level exchange on a 2-axis mesh: a
                          sparse allgather over the inter-node axes
                          composed with a dense psum over the intra-node
                          axis (``sync.hierarchical_allgather``). The slow
                          hop carries p/n_local messages instead of p;
                          reassembly is bit-exact (disjoint psum), so
                          results match ``fused_allgather`` bitwise.
                          Small dense leaves ride the ordinary joint
                          pmean — XLA already routes dense allreduce
                          hierarchically on real topologies, and keeping
                          it joint preserves bitwise parity with the flat
                          transports.
* ``per_leaf_allgather`` — one collective per leaf (the unfused baseline;
                          what fig10's per-message latency term models).
* ``dense_psum``        — dense-only baseline; receiving a sparse message
                          is a configuration error.

All backends share the packed wire format of ``core.sync`` and the dense
psum fallback for small leaves, and accept a ``StageTimer`` hook
(``core.instrument``) for counter-grade facts (``collectives`` and
``messages`` per step). Transports consume *messages*, not leaves: with
``fuse_leaves`` the sync loop hands over ONE pre-packed buffer per
residual arena (``core.arena.pack_group``) which feeds straight into the
fusion/bucketing logic here — the per-leaf transport semantics are
unchanged, there are simply O(arenas) messages instead of O(leaves).
Outside a mesh (``sync_axes=()``) every collective degrades to the
single-worker identity, which is what the CPU smoke tests run.
"""
from __future__ import annotations

from typing import Any

import jax

from . import registry
from . import sync as sync_lib
from .instrument import NullTimer
from .selection import Selected

# Default fused-bucket byte budget. 4 MiB keeps each collective buffer
# well inside ICI/NIC message-size sweet spots while still fusing
# hundreds of small-leaf messages per bucket.
DEFAULT_BUCKET_BYTES = 4 * 1024 * 1024


def assign_buckets(nbytes: list[int], bucket_bytes: int) -> list[list[int]]:
    """Greedy contiguous bucketing of message byte sizes.

    Message ``i`` joins the current bucket unless that would push the
    bucket past ``bucket_bytes``; a message larger than the budget on its
    own still gets a (singleton) bucket — nothing is ever dropped or
    split. Contiguity preserves leaf order, so concat/split offsets match
    the fused layout within each bucket.
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be > 0, got {bucket_bytes}")
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0
    for i, nb in enumerate(nbytes):
        if cur and cur_bytes + nb > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nb
    if cur:
        buckets.append(cur)
    return buckets


class _Base:
    name = "?"

    def __init__(self, sync_axes: tuple[str, ...] = (), timer=None):
        self.sync_axes = tuple(sync_axes)
        self.timer = timer if timer is not None else NullTimer()

    def num_workers(self) -> int:
        from repro.jaxcompat import axis_size
        n = 1
        for ax in self.sync_axes:
            n *= axis_size(ax)
        return n

    def pack(self, sel: Selected, quantized: bool) -> jax.Array:
        return sync_lib.pack(sel, quantized)

    def allreduce_mean(self, grad: jax.Array) -> jax.Array:
        return sync_lib.dense_allreduce_mean(grad, self.sync_axes)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<transport {self.name} axes={self.sync_axes}>"


class FusedAllgather(_Base):
    name = "fused_allgather"

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        if not messages:
            return []
        self.timer.count("messages", len(messages))
        self.timer.count("collectives")
        return sync_lib.fused_allgather(messages, self.sync_axes)


class BucketedAllgather(_Base):
    """§5.3 fusion under a byte budget: one fused allgather per bucket."""

    name = "bucketed_allgather"

    def __init__(self, sync_axes: tuple[str, ...] = (),
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES, timer=None):
        super().__init__(sync_axes, timer)
        self.bucket_bytes = int(bucket_bytes)

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        if not messages:
            return []
        nbytes = [int(m.shape[0]) * m.dtype.itemsize for m in messages]
        buckets = assign_buckets(nbytes, self.bucket_bytes)
        self.timer.count("messages", len(messages))
        self.timer.count("buckets", len(buckets))
        self.timer.count("collectives", len(buckets))
        out: list[jax.Array | None] = [None] * len(messages)
        for idxs in buckets:
            gathered = sync_lib.fused_allgather(
                [messages[i] for i in idxs], self.sync_axes)
            for i, g in zip(idxs, gathered):
                out[i] = g
        return out


class HierarchicalAllgather(_Base):
    """§5.4 intra-node dense psum + inter-node sparse allgather.

    ``intra_axis`` names the fast (intra-node) mesh axis; every other
    sync axis forms the slow inter-node hop. Defaults to the LAST sync
    axis — on the harness's ``("node", "local")`` mesh that is "local",
    and on the production multi-pod ``("pod", "data")`` batch axes it is
    "data" (ICI) with "pod" (DCI) as the inter hop. With fewer than two
    sync axes there is no hierarchy to exploit and the transport degrades
    to the flat fused gather.
    """

    name = "hierarchical"

    def __init__(self, sync_axes: tuple[str, ...] = (),
                 intra_axis: str | None = None, timer=None):
        super().__init__(sync_axes, timer)
        if intra_axis is not None and intra_axis not in self.sync_axes:
            raise ValueError(
                f"intra_axis {intra_axis!r} not among sync_axes "
                f"{self.sync_axes}")
        if intra_axis is None and len(self.sync_axes) >= 2:
            intra_axis = self.sync_axes[-1]
        self.intra_axis = intra_axis if len(self.sync_axes) >= 2 else None
        self.inter_axes = tuple(a for a in self.sync_axes
                                if a != self.intra_axis)

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        if not messages:
            return []
        # same §5.3 fusion as fused_allgather, then the two-level exchange
        lens = [int(m.shape[0]) for m in messages]
        buf = jax.numpy.concatenate(messages)
        self.timer.count("messages", len(messages))
        self.timer.count("collectives", 2 if self.intra_axis else 1)
        gathered = sync_lib.hierarchical_allgather(
            buf, self.inter_axes, self.intra_axis, self.sync_axes)
        return sync_lib.split_rows(gathered, lens)


class PerLeafAllgather(_Base):
    name = "per_leaf_allgather"

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        self.timer.count("messages", len(messages))
        self.timer.count("collectives", len(messages))
        return [sync_lib.sparse_allgather(m, self.sync_axes)
                for m in messages]


class DensePsum(_Base):
    name = "dense_psum"

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        if messages:
            raise NotImplementedError(
                "dense_psum transport cannot carry sparse messages; use "
                "fused_allgather/per_leaf_allgather or a dense-only "
                "dispatch policy")
        return []


@registry.register(registry.TRANSPORT, "fused_allgather")
def _fused(sync_axes: tuple[str, ...] = (), timer=None,
           **_: Any) -> FusedAllgather:
    return FusedAllgather(sync_axes, timer=timer)


@registry.register(registry.TRANSPORT, "bucketed_allgather")
def _bucketed(sync_axes: tuple[str, ...] = (),
              bucket_bytes: int = DEFAULT_BUCKET_BYTES, timer=None,
              **_: Any) -> BucketedAllgather:
    return BucketedAllgather(sync_axes, bucket_bytes=bucket_bytes,
                             timer=timer)


@registry.register(registry.TRANSPORT, "hierarchical")
def _hierarchical(sync_axes: tuple[str, ...] = (),
                  intra_axis: str | None = None, timer=None,
                  **_: Any) -> HierarchicalAllgather:
    return HierarchicalAllgather(sync_axes, intra_axis=intra_axis,
                                 timer=timer)


@registry.register(registry.TRANSPORT, "per_leaf_allgather")
def _per_leaf(sync_axes: tuple[str, ...] = (), timer=None,
              **_: Any) -> PerLeafAllgather:
    return PerLeafAllgather(sync_axes, timer=timer)


@registry.register(registry.TRANSPORT, "dense_psum")
def _dense_psum(sync_axes: tuple[str, ...] = (), timer=None,
                **_: Any) -> DensePsum:
    return DensePsum(sync_axes, timer=timer)
