"""Transport backends: wire packing + collectives over ``sync_axes``.

Three registered backends (§5.3/§5.4):

* ``fused_allgather``   — tensor fusion: concatenate every leaf message
                          into ONE buffer, a single allgather, then split
                          (§5.3 "batch small allgather operations").
* ``per_leaf_allgather`` — one collective per leaf (the unfused baseline;
                          what fig10's per-message latency term models).
* ``dense_psum``        — dense-only baseline; receiving a sparse message
                          is a configuration error.

All backends share the packed wire format of ``core.sync`` and the dense
psum fallback for small leaves. Outside a mesh (``sync_axes=()``) every
collective degrades to the single-worker identity, which is what the CPU
smoke tests run.
"""
from __future__ import annotations

from typing import Any

import jax

from . import registry
from . import sync as sync_lib
from .selection import Selected


class _Base:
    name = "?"

    def __init__(self, sync_axes: tuple[str, ...] = ()):
        self.sync_axes = tuple(sync_axes)

    def num_workers(self) -> int:
        from repro.jaxcompat import axis_size
        n = 1
        for ax in self.sync_axes:
            n *= axis_size(ax)
        return n

    def pack(self, sel: Selected, quantized: bool) -> jax.Array:
        return sync_lib.pack(sel, quantized)

    def allreduce_mean(self, grad: jax.Array) -> jax.Array:
        return sync_lib.dense_allreduce_mean(grad, self.sync_axes)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<transport {self.name} axes={self.sync_axes}>"


class FusedAllgather(_Base):
    name = "fused_allgather"

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        if not messages:
            return []
        return sync_lib.fused_allgather(messages, self.sync_axes)


class PerLeafAllgather(_Base):
    name = "per_leaf_allgather"

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        return [sync_lib.sparse_allgather(m, self.sync_axes)
                for m in messages]


class DensePsum(_Base):
    name = "dense_psum"

    def allgather(self, messages: list[jax.Array]) -> list[jax.Array]:
        if messages:
            raise NotImplementedError(
                "dense_psum transport cannot carry sparse messages; use "
                "fused_allgather/per_leaf_allgather or a dense-only "
                "dispatch policy")
        return []


@registry.register(registry.TRANSPORT, "fused_allgather")
def _fused(sync_axes: tuple[str, ...] = (), **_: Any) -> FusedAllgather:
    return FusedAllgather(sync_axes)


@registry.register(registry.TRANSPORT, "per_leaf_allgather")
def _per_leaf(sync_axes: tuple[str, ...] = (), **_: Any) -> PerLeafAllgather:
    return PerLeafAllgather(sync_axes)


@registry.register(registry.TRANSPORT, "dense_psum")
def _dense_psum(sync_axes: tuple[str, ...] = (), **_: Any) -> DensePsum:
    return DensePsum(sync_axes)
