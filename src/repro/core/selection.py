"""Communication-set selection (RedSync §5.2, Algorithms 2/3/5).

All selectors operate on a flat f32 residual vector and return a
fixed-capacity sparse message ``Selected(indices, values, count)``:

* ``exact_topk``       — radixSelect stand-in (``jax.lax.top_k``); the paper's
                         baseline selector. capacity == k.
* ``trimmed_topk``     — Alg 2: statistics-guided threshold trimming, then an
                         exact top-k restricted to survivors. capacity == k.
* ``threshold_binary_search`` — Alg 3: binary-search a threshold t with
                         k <= nnz(|x|>t) <= 2k; no exact top-k at all.
                         capacity == 2k, padded; true length in ``count``.

Quantized variants (§5.2.3) select by *signed value* (top-k one iteration,
bottom-k the next — the ``phase`` argument) so the communication set is
same-signed and a single scalar mean represents all values.

JAX constraint: shapes are static, so capacity is fixed at trace time. Padding
uses index == size (out of range); decompression drops padded entries via the
``count`` header, mirroring the paper's ``(len, idx, val)`` packed message.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Selected(NamedTuple):
    """Fixed-capacity sparse communication set.

    ``overflow`` is only populated by the threshold-filter selectors
    (whose survivor count is data-dependent); the top-k selectors always
    produce exactly ``k`` survivors and leave it ``None``. Within any one
    selector path the field is consistently an array or consistently
    ``None`` so ``lax.cond`` branches keep matching pytree structures.
    """
    indices: jax.Array   # i32[cap], padded entries == x.size
    values: jax.Array    # f32[cap] (zeros at padding)
    count: jax.Array     # i32[] true number of selected elements (<= cap)
    overflow: jax.Array | None = None  # bool[] nnz exceeded capacity


# Slot alignment granule of the flat residual arenas. Matches the Pallas
# kernels' VMEM block (kernels.ops.DEFAULT_BLOCK) so that a slot's padded
# 2-D view inside an arena is bit-for-bit the view the per-leaf kernels
# build for that leaf on its own.
STATS_BLOCK = 1024


def pinned_sum(v: jax.Array) -> jax.Array:
    """Sum with a PINNED floating-point summation tree (pairwise halving).

    ``jnp.sum``'s partial-sum order is an XLA implementation detail — the
    CPU backend may split one reduce into reduce-window chunks (or not)
    depending on the surrounding fusion, so the same vector can sum to
    last-ulp-different totals in differently-shaped graphs. That breaks
    the flat-arena refactor's bitwise guarantee through the Alg 2/3 mean.
    This sum zero-pads to a power of two and halves with ELEMENTWISE adds
    — elementwise ops have no reduction order for XLA to choose, so the
    addition tree is identical in every graph context.
    """
    flat = v.reshape(-1)
    size = 1 << max(0, int(flat.size - 1).bit_length())
    flat = jnp.pad(flat, (0, size - flat.size))
    while flat.size > 1:
        half = flat.size // 2
        flat = flat[:half] + flat[half:]
    return flat[0]


def mean_of_sum(total: jax.Array, n) -> jax.Array:
    """``total / n`` as a pinned multiply by the f32 reciprocal.

    A literal division by a constant may be strength-reduced to a
    reciprocal multiply under fast math in one graph shape and left as a
    true division in another — a last-ulp lottery, like the FMA
    contraction ``pinned_product`` guards against. Precomputing the f32
    reciprocal in Python and pinning the multiply makes the mean a fixed
    function of ``total`` everywhere. (``n < 2**24`` loses nothing; the
    mean is a selection heuristic, not an accumulator.)

    ``n`` may also be a runtime array (the quantized mean divides by a
    data-dependent count): the reciprocal is then a standalone division
    — never fused, so still a fixed function of its inputs — followed by
    the same pinned multiply.
    """
    from .residual import pinned_product
    if isinstance(n, (int, float)):
        return pinned_product(total, jnp.float32(1.0 / n))
    return pinned_product(total, jnp.float32(1.0) / n.astype(jnp.float32))


def _stats(ax: jax.Array) -> tuple[jax.Array, jax.Array]:
    """mean and max of a non-negative vector (|x|), order-pinned.

    The mean's summation tree is pinned (``pinned_sum``) and the /n is a
    pinned reciprocal multiply (``mean_of_sum``) so per-leaf and
    segmented-arena selection see bitwise-identical statistics; max is
    order-insensitive and stays a plain reduce.
    """
    return mean_of_sum(pinned_sum(ax), ax.size), jnp.max(ax)


def threshold_at(mean: jax.Array, mx: jax.Array,
                 ratio: jax.Array) -> jax.Array:
    """The Alg 2/3 candidate threshold ``mean + ratio * (mx - mean)``.

    The product is contraction-pinned (``residual.pinned_product``): XLA
    would otherwise FMA-contract it in some graph shapes and not others,
    and a last-ulp threshold difference between the per-leaf and
    flat-arena pipelines eventually flips a boundary element of the
    communication set. Shared by the jnp selectors here, the per-leaf
    Pallas wrappers (kernels.ops) and the segmented-arena selectors
    (kernels.segmented) — one definition, bitwise everywhere.
    """
    from .residual import pinned_product
    return mean + pinned_product(ratio, mx - mean)


def bisect_midpoint(l: jax.Array, r: jax.Array) -> jax.Array:
    """``l + (r - l) / 2`` with the halving contraction-pinned.

    XLA strength-reduces the ``/ 2.0`` to ``* 0.5`` (value-identical)
    and may then FMA-contract it with the ``l +`` — graph-shape
    dependent, like ``threshold_at``'s product. Same pin, same reason.
    """
    from .residual import pinned_product
    return l + pinned_product(jnp.float32(0.5), r - l)


def ladder_ratio(step: jax.Array, eps) -> jax.Array:
    """Alg 2 ratio after ``step`` rungs: ``1 - step * eps``, pinned.

    The naive ladder (``ratio -= eps`` in the loop carry) accumulates f32
    decrement error — five steps of 0.2 land at 4.5e-8, not 0.0, which
    admits a spurious near-zero extra iteration. Recomputing each rung
    from the integer step count with one pinned product makes the rung
    values exact at representable boundaries and — more importantly —
    identical between the scalar per-leaf loops and the vectorized
    segmented loops at every step.

    ``step`` is i32 (scalar or per-segment vector); ``eps`` a float or
    f32 vector.
    """
    from .residual import pinned_product
    eps = jnp.asarray(eps, jnp.float32)
    return jnp.float32(1.0) - pinned_product(step.astype(jnp.float32), eps)


def warm_ratio(thr: jax.Array, mean: jax.Array, mx: jax.Array) -> jax.Array:
    """A previous threshold's ratio coordinate under the *current* stats.

    Inverse of ``threshold_at``, clipped into the ``[0, 1]`` search
    interval; degenerate spans (``mx <= mean``) map to 0 so a warm start
    on them degrades to the cold bracket. The reciprocal is a standalone
    division and the multiply is contraction-pinned, keeping the scalar
    per-leaf and vectorized segmented versions elementwise identical.
    """
    from .residual import pinned_product
    span = mx - mean
    safe = jnp.maximum(span, jnp.float32(1e-30))
    r = pinned_product(thr - mean, jnp.float32(1.0) / safe)
    return jnp.clip(jnp.where(span > 0, r, jnp.float32(0.0)), 0.0, 1.0)


def _pad_topk(x: jax.Array, score: jax.Array, k: int) -> Selected:
    """Exact top-k by ``score``; values taken from ``x``."""
    _, idx = jax.lax.top_k(score, k)
    return Selected(idx.astype(jnp.int32), x[idx], jnp.int32(k))


# ---------------------------------------------------------------------------
# Baseline: exact top-k (the "radixSelect" reference point)
# ---------------------------------------------------------------------------

def exact_topk(x: jax.Array, k: int) -> Selected:
    return _pad_topk(x, jnp.abs(x), k)


# ---------------------------------------------------------------------------
# Algorithm 2: trimmed top-k
# ---------------------------------------------------------------------------

def trimmed_topk(x: jax.Array, k: int, eps: float = 0.2) -> Selected:
    """Find a threshold that keeps >=k survivors, then top-k the survivors.

    Survivor restriction is expressed by zeroing the score of trimmed
    elements; on TPU the survivor set is first compacted into a small buffer
    by the Pallas block-bucketed compaction kernel (kernels/compact.py), which
    is where the paper's speedup comes from. The selected set is identical.
    """
    ax = jnp.abs(x)
    mean, mx = _stats(ax)

    def cond(state):
        step, nnz = state
        return jnp.logical_and(nnz < k, ladder_ratio(step, eps) > 0.0)

    def body(state):
        step, _ = state
        step = step + 1
        thr = threshold_at(mean, mx, ladder_ratio(step, eps))
        return step, jnp.sum(ax > thr)

    step0 = jnp.int32(1)
    nnz0 = jnp.sum(ax > threshold_at(mean, mx, ladder_ratio(step0, eps)))
    step, _ = jax.lax.while_loop(cond, body, (step0, nnz0))
    thr = threshold_at(mean, mx, ladder_ratio(step, eps))
    trimmed_score = jnp.where(ax > thr, ax, 0.0)
    return _pad_topk(x, trimmed_score, k)


# ---------------------------------------------------------------------------
# Algorithm 3: threshold binary search selection
# ---------------------------------------------------------------------------

def search_band(count_at, mean: jax.Array, mx: jax.Array, k: int,
                eps: float, warm: jax.Array | None = None) -> jax.Array:
    """The Alg 3 bisection: a threshold t with ``k <= count_at(t) <= 2k``.

    ``count_at`` maps a threshold to an i32 survivor count — a full scan
    for the exact selectors, a strided-subsample count (scaled back up)
    for the sampled ones, a Pallas count kernel for the per-leaf kernel
    path. Parameterizing the count is what keeps all three paths walking
    the *same* pinned iterate sequence.

    ``warm`` (§5.2.2 pushed further): the previous step's converged
    threshold. It is first probed — if its count is already in band the
    search exits with zero iterations — otherwise its ratio coordinate
    seeds the bracket (``(0, r_prev)`` when the count fell below ``k``,
    ``(r_prev, 1)`` when above ``2k``), shrinking the cold ``(0, 1)``
    interval to the residual drift since last step. ``warm=None`` is the
    cold search, bitwise-identical to the pre-warm-start code.
    """
    def in_band(n):
        return jnp.logical_and(n >= k, n <= 2 * k)

    if warm is None:
        l0, r0 = jnp.float32(0.0), jnp.float32(1.0)
        nnz0 = jnp.int32(-1)
    else:
        nnz0 = count_at(warm)
        accept = in_band(nnz0)
        r_prev = warm_ratio(warm, mean, mx)
        l0 = jnp.where(nnz0 > 2 * k, r_prev, jnp.float32(0.0))
        r0 = jnp.where(nnz0 < k, r_prev, jnp.float32(1.0))

    def cond(state):
        l, r, nnz = state
        return jnp.logical_and(~in_band(nnz), (r - l) > eps)

    def body(state):
        l, r, _ = state
        ratio = bisect_midpoint(l, r)
        nnz = count_at(threshold_at(mean, mx, ratio))
        # nnz too small -> threshold too high -> move right bound down
        r = jnp.where(nnz < k, ratio, r)
        l = jnp.where(nnz > 2 * k, ratio, l)
        return l, r, nnz

    l, r, _ = jax.lax.while_loop(cond, body, (l0, r0, nnz0))
    thr = threshold_at(mean, mx, bisect_midpoint(l, r))
    if warm is not None:
        thr = jnp.where(accept, warm, thr)
    return thr


def threshold_binary_search(
    x: jax.Array,
    k: int,
    eps: float = 1e-3,
    threshold: jax.Array | None = None,
    *,
    warm: jax.Array | None = None,
) -> tuple[Selected, jax.Array]:
    """Binary-search a threshold t with k <= nnz(|x|>t) <= 2k.

    Returns the selection *and* the threshold so callers can implement the
    paper's "sampled" variant (reuse the threshold for the next `interval`
    iterations via ``threshold_filter``). capacity == 2k.

    ``threshold`` short-circuits the whole search (§5.2.2 reuse): the
    cached threshold is applied directly, no statistics and no bisection
    are traced. ``warm`` seeds the bisection bracket from the previous
    converged threshold (see ``search_band``) while still re-searching.
    """
    if threshold is not None:
        # Reuse branch: filter at the cached threshold. (This used to run
        # the full bisection while_loop and then discard its result.)
        return threshold_filter(x, threshold, capacity=2 * k), threshold
    ax = jnp.abs(x)
    mean, mx = _stats(ax)
    thr = search_band(lambda t: jnp.sum(ax > t), mean, mx, k, eps, warm)
    return threshold_filter(x, thr, capacity=2 * k), thr


def sampled_threshold_search(
    x: jax.Array,
    k: int,
    *,
    stride: int,
    capacity: int,
    eps: float = 1e-3,
    warm: jax.Array | None = None,
) -> tuple[Selected, jax.Array]:
    """DGC-style sampled Alg 3: search on a strided subsample of ``x``.

    Statistics (mean/max) and every bisection count come from
    ``x[::stride]`` — an O(n/stride) scan per iteration instead of O(n) —
    with the subsample count scaled by ``stride`` as the nnz estimate.
    Only the final filter touches the full vector, so its ``count``
    header is the *true* survivor count and its ``overflow`` flag catches
    under-estimates that blow past ``capacity`` (the caller sizes
    ``capacity`` with tolerance headroom; ``cost_model.sample_stride``
    derives ``stride`` from ``k`` and the documented tolerance).
    ``stride=1`` is bitwise-identical to ``threshold_binary_search``.
    """
    flat = x.reshape(-1)
    xs = flat[::stride] if stride > 1 else flat
    axs = jnp.abs(xs)
    mean, mx = _stats(axs)
    thr = search_band(lambda t: jnp.sum(axs > t) * stride,
                      mean, mx, k, eps, warm)
    return threshold_filter(x, thr, capacity=capacity), thr


def threshold_filter(x: jax.Array, threshold: jax.Array, capacity: int) -> Selected:
    """All elements with |x| > threshold, first-`capacity`, padded (Alg 5 L40).

    Overflow semantics (pinned): when ``nnz > capacity`` the first
    ``capacity`` survivors in *index* order are kept — lowest indices
    win, NOT the largest magnitudes — the ``count`` header saturates at
    ``capacity``, and ``overflow`` is set so the pipeline can surface the
    silent drop (GradientSync counts it as ``select_overflow`` on the
    stage timer; the transport bench reports it). Shapes are static, so
    the alternative — growing the message — does not exist; the flag is
    the contract.
    """
    ax = jnp.abs(x)
    mask = ax > threshold
    nnz = jnp.sum(mask)
    (idx,) = jnp.nonzero(mask, size=capacity, fill_value=x.size)
    safe = jnp.minimum(idx, x.size - 1)
    vals = jnp.where(idx < x.size, x[safe], 0.0)
    return Selected(idx.astype(jnp.int32), vals, jnp.minimum(nnz, capacity),
                    nnz > capacity)


# ---------------------------------------------------------------------------
# Quantized variants (§5.2.3): same-signed communication sets
# ---------------------------------------------------------------------------

def _signed_score(x: jax.Array, phase: jax.Array) -> jax.Array:
    """Score for alternating top/bottom selection.

    phase == 0 -> select largest values (positives); phase == 1 -> most
    negative values. Elements of the wrong sign get score 0 so they are never
    selected ahead of a same-signed element.
    """
    y = jnp.where(phase == 0, x, -x)
    return jnp.maximum(y, 0.0)


def exact_topk_quant(x: jax.Array, k: int, phase: jax.Array) -> Selected:
    score = _signed_score(x, phase)
    sel = _pad_topk(x, score, k)
    return _quantize(sel, x.size)


def trimmed_topk_quant(
    x: jax.Array, k: int, phase: jax.Array, eps: float = 0.2
) -> Selected:
    score = _signed_score(x, phase)
    mean, mx = _stats(score)

    def cond(state):
        step, nnz = state
        return jnp.logical_and(nnz < k, ladder_ratio(step, eps) > 0.0)

    def body(state):
        step, _ = state
        step = step + 1
        thr = threshold_at(mean, mx, ladder_ratio(step, eps))
        return step, jnp.sum(score > thr)

    step0 = jnp.int32(1)
    nnz0 = jnp.sum(score > threshold_at(mean, mx, ladder_ratio(step0, eps)))
    step, _ = jax.lax.while_loop(cond, body, (step0, nnz0))
    thr = threshold_at(mean, mx, ladder_ratio(step, eps))
    sel = _pad_topk(x, jnp.where(score > thr, score, 0.0), k)
    return _quantize(sel, x.size)


def threshold_binary_search_quant(
    x: jax.Array, k: int, phase: jax.Array, eps: float = 1e-3
) -> Selected:
    """Binary-search variant on the signed score, then quantize.

    The paper notes threshold *sharing* is incompatible with quantization
    (the sign phase alternates every iteration), so no threshold is returned.
    """
    score = _signed_score(x, phase)
    mean, mx = _stats(score)
    thr = search_band(lambda t: jnp.sum(score > t), mean, mx, k, eps)
    mask = score > thr
    nnz = jnp.sum(mask)
    (idx,) = jnp.nonzero(mask, size=2 * k, fill_value=x.size)
    safe = jnp.minimum(idx, x.size - 1)
    vals = jnp.where(idx < x.size, x[safe], 0.0)
    sel = Selected(idx.astype(jnp.int32), vals, jnp.minimum(nnz, 2 * k),
                   nnz > 2 * k)
    return _quantize(sel, x.size)


def _quantize(sel: Selected, size: int) -> Selected:
    """Replace per-element values by their mean (broadcast at decompression).

    The mean is stored in values[0]; the rest of the value payload is unused
    on the wire (sync.py transmits only (count, indices, mean) for quantized
    messages). Values here are reconstructed dense so masking/decompression
    code paths stay uniform.
    """
    valid = sel.indices < size
    total = pinned_sum(jnp.where(valid, sel.values, 0.0))
    mean = mean_of_sum(total, jnp.maximum(sel.count, 1))
    return Selected(sel.indices, jnp.where(valid, mean, 0.0), sel.count,
                    sel.overflow)
