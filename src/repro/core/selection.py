"""Communication-set selection (RedSync §5.2, Algorithms 2/3/5).

All selectors operate on a flat f32 residual vector and return a
fixed-capacity sparse message ``Selected(indices, values, count)``:

* ``exact_topk``       — radixSelect stand-in (``jax.lax.top_k``); the paper's
                         baseline selector. capacity == k.
* ``trimmed_topk``     — Alg 2: statistics-guided threshold trimming, then an
                         exact top-k restricted to survivors. capacity == k.
* ``threshold_binary_search`` — Alg 3: binary-search a threshold t with
                         k <= nnz(|x|>t) <= 2k; no exact top-k at all.
                         capacity == 2k, padded; true length in ``count``.

Quantized variants (§5.2.3) select by *signed value* (top-k one iteration,
bottom-k the next — the ``phase`` argument) so the communication set is
same-signed and a single scalar mean represents all values.

JAX constraint: shapes are static, so capacity is fixed at trace time. Padding
uses index == size (out of range); decompression drops padded entries via the
``count`` header, mirroring the paper's ``(len, idx, val)`` packed message.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Selected(NamedTuple):
    """Fixed-capacity sparse communication set."""
    indices: jax.Array   # i32[cap], padded entries == x.size
    values: jax.Array    # f32[cap] (zeros at padding)
    count: jax.Array     # i32[] true number of selected elements (<= cap)


def _stats(ax: jax.Array) -> tuple[jax.Array, jax.Array]:
    """mean and max of a non-negative vector (|x|)."""
    return jnp.mean(ax), jnp.max(ax)


def _pad_topk(x: jax.Array, score: jax.Array, k: int) -> Selected:
    """Exact top-k by ``score``; values taken from ``x``."""
    _, idx = jax.lax.top_k(score, k)
    return Selected(idx.astype(jnp.int32), x[idx], jnp.int32(k))


# ---------------------------------------------------------------------------
# Baseline: exact top-k (the "radixSelect" reference point)
# ---------------------------------------------------------------------------

def exact_topk(x: jax.Array, k: int) -> Selected:
    return _pad_topk(x, jnp.abs(x), k)


# ---------------------------------------------------------------------------
# Algorithm 2: trimmed top-k
# ---------------------------------------------------------------------------

def trimmed_topk(x: jax.Array, k: int, eps: float = 0.2) -> Selected:
    """Find a threshold that keeps >=k survivors, then top-k the survivors.

    Survivor restriction is expressed by zeroing the score of trimmed
    elements; on TPU the survivor set is first compacted into a small buffer
    by the Pallas block-bucketed compaction kernel (kernels/compact.py), which
    is where the paper's speedup comes from. The selected set is identical.
    """
    ax = jnp.abs(x)
    mean, mx = _stats(ax)

    def cond(state):
        ratio, nnz = state
        return jnp.logical_and(nnz < k, ratio > 0.0)

    def body(state):
        ratio, _ = state
        ratio = ratio - eps
        thr = mean + ratio * (mx - mean)
        return ratio, jnp.sum(ax > thr)

    ratio0 = 1.0 - eps
    nnz0 = jnp.sum(ax > mean + ratio0 * (mx - mean))
    ratio, _ = jax.lax.while_loop(cond, body, (jnp.float32(ratio0), nnz0))
    thr = mean + ratio * (mx - mean)
    trimmed_score = jnp.where(ax > thr, ax, 0.0)
    return _pad_topk(x, trimmed_score, k)


# ---------------------------------------------------------------------------
# Algorithm 3: threshold binary search selection
# ---------------------------------------------------------------------------

def threshold_binary_search(
    x: jax.Array,
    k: int,
    eps: float = 1e-3,
    threshold: jax.Array | None = None,
) -> tuple[Selected, jax.Array]:
    """Binary-search a threshold t with k <= nnz(|x|>t) <= 2k.

    Returns the selection *and* the threshold so callers can implement the
    paper's "sampled" variant (reuse the threshold for the next `interval`
    iterations via ``threshold_filter``). capacity == 2k.
    """
    ax = jnp.abs(x)
    mean, mx = _stats(ax)

    def cond(state):
        l, r, nnz = state
        done = jnp.logical_and(nnz >= k, nnz <= 2 * k)
        return jnp.logical_and(~done, (r - l) > eps)

    def body(state):
        l, r, _ = state
        ratio = l + (r - l) / 2.0
        thr = mean + ratio * (mx - mean)
        nnz = jnp.sum(ax > thr)
        # nnz too small -> threshold too high -> move right bound down
        r = jnp.where(nnz < k, ratio, r)
        l = jnp.where(nnz > 2 * k, ratio, l)
        return l, r, nnz

    l, r, _ = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), jnp.float32(1.0), jnp.int32(-1))
    )
    ratio = l + (r - l) / 2.0
    thr = mean + ratio * (mx - mean)
    if threshold is not None:  # pragma: no cover - convenience branch
        thr = threshold
    return threshold_filter(x, thr, capacity=2 * k), thr


def threshold_filter(x: jax.Array, threshold: jax.Array, capacity: int) -> Selected:
    """All elements with |x| > threshold, first-`capacity`, padded (Alg 5 L40)."""
    ax = jnp.abs(x)
    mask = ax > threshold
    nnz = jnp.sum(mask)
    (idx,) = jnp.nonzero(mask, size=capacity, fill_value=x.size)
    safe = jnp.minimum(idx, x.size - 1)
    vals = jnp.where(idx < x.size, x[safe], 0.0)
    return Selected(idx.astype(jnp.int32), vals, jnp.minimum(nnz, capacity))


# ---------------------------------------------------------------------------
# Quantized variants (§5.2.3): same-signed communication sets
# ---------------------------------------------------------------------------

def _signed_score(x: jax.Array, phase: jax.Array) -> jax.Array:
    """Score for alternating top/bottom selection.

    phase == 0 -> select largest values (positives); phase == 1 -> most
    negative values. Elements of the wrong sign get score 0 so they are never
    selected ahead of a same-signed element.
    """
    y = jnp.where(phase == 0, x, -x)
    return jnp.maximum(y, 0.0)


def exact_topk_quant(x: jax.Array, k: int, phase: jax.Array) -> Selected:
    score = _signed_score(x, phase)
    sel = _pad_topk(x, score, k)
    return _quantize(sel, x.size)


def trimmed_topk_quant(
    x: jax.Array, k: int, phase: jax.Array, eps: float = 0.2
) -> Selected:
    score = _signed_score(x, phase)
    mean, mx = _stats(score)

    def cond(state):
        ratio, nnz = state
        return jnp.logical_and(nnz < k, ratio > 0.0)

    def body(state):
        ratio, _ = state
        ratio = ratio - eps
        return ratio, jnp.sum(score > mean + ratio * (mx - mean))

    ratio0 = 1.0 - eps
    nnz0 = jnp.sum(score > mean + ratio0 * (mx - mean))
    ratio, _ = jax.lax.while_loop(cond, body, (jnp.float32(ratio0), nnz0))
    thr = mean + ratio * (mx - mean)
    sel = _pad_topk(x, jnp.where(score > thr, score, 0.0), k)
    return _quantize(sel, x.size)


def threshold_binary_search_quant(
    x: jax.Array, k: int, phase: jax.Array, eps: float = 1e-3
) -> Selected:
    """Binary-search variant on the signed score, then quantize.

    The paper notes threshold *sharing* is incompatible with quantization
    (the sign phase alternates every iteration), so no threshold is returned.
    """
    score = _signed_score(x, phase)
    mean, mx = _stats(score)

    def cond(state):
        l, r, nnz = state
        done = jnp.logical_and(nnz >= k, nnz <= 2 * k)
        return jnp.logical_and(~done, (r - l) > eps)

    def body(state):
        l, r, _ = state
        ratio = l + (r - l) / 2.0
        thr = mean + ratio * (mx - mean)
        nnz = jnp.sum(score > thr)
        r = jnp.where(nnz < k, ratio, r)
        l = jnp.where(nnz > 2 * k, ratio, l)
        return l, r, nnz

    l, r, _ = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), jnp.float32(1.0), jnp.int32(-1))
    )
    thr = mean + (l + (r - l) / 2.0) * (mx - mean)
    mask = score > thr
    nnz = jnp.sum(mask)
    (idx,) = jnp.nonzero(mask, size=2 * k, fill_value=x.size)
    safe = jnp.minimum(idx, x.size - 1)
    vals = jnp.where(idx < x.size, x[safe], 0.0)
    sel = Selected(idx.astype(jnp.int32), vals, jnp.minimum(nnz, 2 * k))
    return _quantize(sel, x.size)


def _quantize(sel: Selected, size: int) -> Selected:
    """Replace per-element values by their mean (broadcast at decompression).

    The mean is stored in values[0]; the rest of the value payload is unused
    on the wire (sync.py transmits only (count, indices, mean) for quantized
    messages). Values here are reconstructed dense so masking/decompression
    code paths stay uniform.
    """
    valid = sel.indices < size
    denom = jnp.maximum(sel.count, 1).astype(jnp.float32)
    mean = jnp.sum(jnp.where(valid, sel.values, 0.0)) / denom
    return Selected(sel.indices, jnp.where(valid, mean, 0.0), sel.count)
