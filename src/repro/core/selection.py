"""Communication-set selection (RedSync §5.2, Algorithms 2/3/5).

All selectors operate on a flat f32 residual vector and return a
fixed-capacity sparse message ``Selected(indices, values, count)``:

* ``exact_topk``       — radixSelect stand-in (``jax.lax.top_k``); the paper's
                         baseline selector. capacity == k.
* ``trimmed_topk``     — Alg 2: statistics-guided threshold trimming, then an
                         exact top-k restricted to survivors. capacity == k.
* ``threshold_binary_search`` — Alg 3: binary-search a threshold t with
                         k <= nnz(|x|>t) <= 2k; no exact top-k at all.
                         capacity == 2k, padded; true length in ``count``.

Quantized variants (§5.2.3) select by *signed value* (top-k one iteration,
bottom-k the next — the ``phase`` argument) so the communication set is
same-signed and a single scalar mean represents all values.

JAX constraint: shapes are static, so capacity is fixed at trace time. Padding
uses index == size (out of range); decompression drops padded entries via the
``count`` header, mirroring the paper's ``(len, idx, val)`` packed message.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Selected(NamedTuple):
    """Fixed-capacity sparse communication set."""
    indices: jax.Array   # i32[cap], padded entries == x.size
    values: jax.Array    # f32[cap] (zeros at padding)
    count: jax.Array     # i32[] true number of selected elements (<= cap)


# Slot alignment granule of the flat residual arenas. Matches the Pallas
# kernels' VMEM block (kernels.ops.DEFAULT_BLOCK) so that a slot's padded
# 2-D view inside an arena is bit-for-bit the view the per-leaf kernels
# build for that leaf on its own.
STATS_BLOCK = 1024


def pinned_sum(v: jax.Array) -> jax.Array:
    """Sum with a PINNED floating-point summation tree (pairwise halving).

    ``jnp.sum``'s partial-sum order is an XLA implementation detail — the
    CPU backend may split one reduce into reduce-window chunks (or not)
    depending on the surrounding fusion, so the same vector can sum to
    last-ulp-different totals in differently-shaped graphs. That breaks
    the flat-arena refactor's bitwise guarantee through the Alg 2/3 mean.
    This sum zero-pads to a power of two and halves with ELEMENTWISE adds
    — elementwise ops have no reduction order for XLA to choose, so the
    addition tree is identical in every graph context.
    """
    flat = v.reshape(-1)
    size = 1 << max(0, int(flat.size - 1).bit_length())
    flat = jnp.pad(flat, (0, size - flat.size))
    while flat.size > 1:
        half = flat.size // 2
        flat = flat[:half] + flat[half:]
    return flat[0]


def mean_of_sum(total: jax.Array, n: int) -> jax.Array:
    """``total / n`` as a pinned multiply by the f32 reciprocal.

    A literal division by a constant may be strength-reduced to a
    reciprocal multiply under fast math in one graph shape and left as a
    true division in another — a last-ulp lottery, like the FMA
    contraction ``pinned_product`` guards against. Precomputing the f32
    reciprocal in Python and pinning the multiply makes the mean a fixed
    function of ``total`` everywhere. (``n < 2**24`` loses nothing; the
    mean is a selection heuristic, not an accumulator.)
    """
    from .residual import pinned_product
    return pinned_product(total, jnp.float32(1.0 / n))


def _stats(ax: jax.Array) -> tuple[jax.Array, jax.Array]:
    """mean and max of a non-negative vector (|x|), order-pinned.

    The mean's summation tree is pinned (``pinned_sum``) and the /n is a
    pinned reciprocal multiply (``mean_of_sum``) so per-leaf and
    segmented-arena selection see bitwise-identical statistics; max is
    order-insensitive and stays a plain reduce.
    """
    return mean_of_sum(pinned_sum(ax), ax.size), jnp.max(ax)


def threshold_at(mean: jax.Array, mx: jax.Array,
                 ratio: jax.Array) -> jax.Array:
    """The Alg 2/3 candidate threshold ``mean + ratio * (mx - mean)``.

    The product is contraction-pinned (``residual.pinned_product``): XLA
    would otherwise FMA-contract it in some graph shapes and not others,
    and a last-ulp threshold difference between the per-leaf and
    flat-arena pipelines eventually flips a boundary element of the
    communication set. Shared by the jnp selectors here, the per-leaf
    Pallas wrappers (kernels.ops) and the segmented-arena selectors
    (kernels.segmented) — one definition, bitwise everywhere.
    """
    from .residual import pinned_product
    return mean + pinned_product(ratio, mx - mean)


def bisect_midpoint(l: jax.Array, r: jax.Array) -> jax.Array:
    """``l + (r - l) / 2`` with the halving contraction-pinned.

    XLA strength-reduces the ``/ 2.0`` to ``* 0.5`` (value-identical)
    and may then FMA-contract it with the ``l +`` — graph-shape
    dependent, like ``threshold_at``'s product. Same pin, same reason.
    """
    from .residual import pinned_product
    return l + pinned_product(jnp.float32(0.5), r - l)


def _pad_topk(x: jax.Array, score: jax.Array, k: int) -> Selected:
    """Exact top-k by ``score``; values taken from ``x``."""
    _, idx = jax.lax.top_k(score, k)
    return Selected(idx.astype(jnp.int32), x[idx], jnp.int32(k))


# ---------------------------------------------------------------------------
# Baseline: exact top-k (the "radixSelect" reference point)
# ---------------------------------------------------------------------------

def exact_topk(x: jax.Array, k: int) -> Selected:
    return _pad_topk(x, jnp.abs(x), k)


# ---------------------------------------------------------------------------
# Algorithm 2: trimmed top-k
# ---------------------------------------------------------------------------

def trimmed_topk(x: jax.Array, k: int, eps: float = 0.2) -> Selected:
    """Find a threshold that keeps >=k survivors, then top-k the survivors.

    Survivor restriction is expressed by zeroing the score of trimmed
    elements; on TPU the survivor set is first compacted into a small buffer
    by the Pallas block-bucketed compaction kernel (kernels/compact.py), which
    is where the paper's speedup comes from. The selected set is identical.
    """
    ax = jnp.abs(x)
    mean, mx = _stats(ax)

    def cond(state):
        ratio, nnz = state
        return jnp.logical_and(nnz < k, ratio > 0.0)

    def body(state):
        ratio, _ = state
        ratio = ratio - eps
        thr = threshold_at(mean, mx, ratio)
        return ratio, jnp.sum(ax > thr)

    ratio0 = 1.0 - eps
    nnz0 = jnp.sum(ax > threshold_at(mean, mx, jnp.float32(ratio0)))
    ratio, _ = jax.lax.while_loop(cond, body, (jnp.float32(ratio0), nnz0))
    thr = threshold_at(mean, mx, ratio)
    trimmed_score = jnp.where(ax > thr, ax, 0.0)
    return _pad_topk(x, trimmed_score, k)


# ---------------------------------------------------------------------------
# Algorithm 3: threshold binary search selection
# ---------------------------------------------------------------------------

def threshold_binary_search(
    x: jax.Array,
    k: int,
    eps: float = 1e-3,
    threshold: jax.Array | None = None,
) -> tuple[Selected, jax.Array]:
    """Binary-search a threshold t with k <= nnz(|x|>t) <= 2k.

    Returns the selection *and* the threshold so callers can implement the
    paper's "sampled" variant (reuse the threshold for the next `interval`
    iterations via ``threshold_filter``). capacity == 2k.
    """
    ax = jnp.abs(x)
    mean, mx = _stats(ax)

    def cond(state):
        l, r, nnz = state
        done = jnp.logical_and(nnz >= k, nnz <= 2 * k)
        return jnp.logical_and(~done, (r - l) > eps)

    def body(state):
        l, r, _ = state
        ratio = bisect_midpoint(l, r)
        thr = threshold_at(mean, mx, ratio)
        nnz = jnp.sum(ax > thr)
        # nnz too small -> threshold too high -> move right bound down
        r = jnp.where(nnz < k, ratio, r)
        l = jnp.where(nnz > 2 * k, ratio, l)
        return l, r, nnz

    l, r, _ = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), jnp.float32(1.0), jnp.int32(-1))
    )
    ratio = bisect_midpoint(l, r)
    thr = threshold_at(mean, mx, ratio)
    if threshold is not None:  # pragma: no cover - convenience branch
        thr = threshold
    return threshold_filter(x, thr, capacity=2 * k), thr


def threshold_filter(x: jax.Array, threshold: jax.Array, capacity: int) -> Selected:
    """All elements with |x| > threshold, first-`capacity`, padded (Alg 5 L40)."""
    ax = jnp.abs(x)
    mask = ax > threshold
    nnz = jnp.sum(mask)
    (idx,) = jnp.nonzero(mask, size=capacity, fill_value=x.size)
    safe = jnp.minimum(idx, x.size - 1)
    vals = jnp.where(idx < x.size, x[safe], 0.0)
    return Selected(idx.astype(jnp.int32), vals, jnp.minimum(nnz, capacity))


# ---------------------------------------------------------------------------
# Quantized variants (§5.2.3): same-signed communication sets
# ---------------------------------------------------------------------------

def _signed_score(x: jax.Array, phase: jax.Array) -> jax.Array:
    """Score for alternating top/bottom selection.

    phase == 0 -> select largest values (positives); phase == 1 -> most
    negative values. Elements of the wrong sign get score 0 so they are never
    selected ahead of a same-signed element.
    """
    y = jnp.where(phase == 0, x, -x)
    return jnp.maximum(y, 0.0)


def exact_topk_quant(x: jax.Array, k: int, phase: jax.Array) -> Selected:
    score = _signed_score(x, phase)
    sel = _pad_topk(x, score, k)
    return _quantize(sel, x.size)


def trimmed_topk_quant(
    x: jax.Array, k: int, phase: jax.Array, eps: float = 0.2
) -> Selected:
    score = _signed_score(x, phase)
    mean, mx = _stats(score)

    def cond(state):
        ratio, nnz = state
        return jnp.logical_and(nnz < k, ratio > 0.0)

    def body(state):
        ratio, _ = state
        ratio = ratio - eps
        return ratio, jnp.sum(score > threshold_at(mean, mx, ratio))

    ratio0 = 1.0 - eps
    nnz0 = jnp.sum(score > threshold_at(mean, mx, jnp.float32(ratio0)))
    ratio, _ = jax.lax.while_loop(cond, body, (jnp.float32(ratio0), nnz0))
    thr = threshold_at(mean, mx, ratio)
    sel = _pad_topk(x, jnp.where(score > thr, score, 0.0), k)
    return _quantize(sel, x.size)


def threshold_binary_search_quant(
    x: jax.Array, k: int, phase: jax.Array, eps: float = 1e-3
) -> Selected:
    """Binary-search variant on the signed score, then quantize.

    The paper notes threshold *sharing* is incompatible with quantization
    (the sign phase alternates every iteration), so no threshold is returned.
    """
    score = _signed_score(x, phase)
    mean, mx = _stats(score)

    def cond(state):
        l, r, nnz = state
        done = jnp.logical_and(nnz >= k, nnz <= 2 * k)
        return jnp.logical_and(~done, (r - l) > eps)

    def body(state):
        l, r, _ = state
        ratio = bisect_midpoint(l, r)
        thr = threshold_at(mean, mx, ratio)
        nnz = jnp.sum(score > thr)
        r = jnp.where(nnz < k, ratio, r)
        l = jnp.where(nnz > 2 * k, ratio, l)
        return l, r, nnz

    l, r, _ = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), jnp.float32(1.0), jnp.int32(-1))
    )
    thr = threshold_at(mean, mx, bisect_midpoint(l, r))
    mask = score > thr
    nnz = jnp.sum(mask)
    (idx,) = jnp.nonzero(mask, size=2 * k, fill_value=x.size)
    safe = jnp.minimum(idx, x.size - 1)
    vals = jnp.where(idx < x.size, x[safe], 0.0)
    sel = Selected(idx.astype(jnp.int32), vals, jnp.minimum(nnz, 2 * k))
    return _quantize(sel, x.size)


def _quantize(sel: Selected, size: int) -> Selected:
    """Replace per-element values by their mean (broadcast at decompression).

    The mean is stored in values[0]; the rest of the value payload is unused
    on the wire (sync.py transmits only (count, indices, mean) for quantized
    messages). Values here are reconstructed dense so masking/decompression
    code paths stay uniform.
    """
    valid = sel.indices < size
    denom = jnp.maximum(sel.count, 1).astype(jnp.float32)
    mean = jnp.sum(jnp.where(valid, sel.values, 0.0)) / denom
    return Selected(sel.indices, jnp.where(valid, mean, 0.0), sel.count)
