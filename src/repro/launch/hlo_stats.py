"""Parse collective ops + byte counts out of compiled HLO text.

``cost_analysis()`` has no collective-bytes entry, so the roofline's third
term comes from here: we walk every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` instruction in
the (SPMD-partitioned) module and compute per-device WIRE bytes from the
instruction's result shape and replica-group size with ring-algorithm
algebra:

    all-reduce:          2 * (g-1)/g * bytes(result)
    all-gather:              (g-1)/g * bytes(result)      (result = g*operand)
    reduce-scatter:          (g-1)   * bytes(result)      (operand = g*result)
    all-to-all:              (g-1)/g * bytes(result)
    collective-permute:                bytes(result)

Group size g is parsed from replica_groups (explicit ``{{0,1,...}}`` lists or
iota ``[n,g]<=[...]`` form).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class Collective:
    op: str
    result_bytes: int
    group_size: int
    wire_bytes: int       # per-device bytes on the wire (ring algebra)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _wire(op: str, result_bytes: int, g: int) -> int:
    if g <= 1:
        return 0
    if op == "all-reduce":
        return int(2 * (g - 1) / g * result_bytes)
    if op == "all-gather":
        return int((g - 1) / g * result_bytes)
    if op == "reduce-scatter":
        return int((g - 1) * result_bytes)
    if op == "all-to-all":
        return int((g - 1) / g * result_bytes)
    return result_bytes      # collective-permute


def parse_collectives(hlo_text: str) -> list[Collective]:
    out: list[Collective] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:       # started op already counted at -start
            continue
        tuple_body, dtype, dims, op = m.groups()
        if tuple_body is not None:
            rb = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(tuple_body))
        else:
            rb = _shape_bytes(dtype, dims)
        gm = _GROUPS_LIST_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            g = int(gi.group(2)) if gi else 1
        out.append(Collective(op, rb, g, _wire(op, rb, g)))
    return out


def collective_summary(hlo_text: str) -> dict:
    colls = parse_collectives(hlo_text)
    by_op: dict[str, dict] = {}
    for c in colls:
        d = by_op.setdefault(c.op, {"count": 0, "result_bytes": 0,
                                    "wire_bytes": 0})
        d["count"] += 1
        d["result_bytes"] += c.result_bytes
        d["wire_bytes"] += c.wire_bytes
    return {
        "total_wire_bytes": sum(c.wire_bytes for c in colls),
        "total_count": len(colls),
        "by_op": by_op,
    }
