"""Production mesh factory (TPU v5e target).

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is an additional pure data-parallel dimension over DCI; RGC's sparse
allgather syncs over ("pod", "data").

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run needs to set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh over forced host devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
