"""Production mesh factory (TPU v5e target).

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis is an additional pure data-parallel dimension over DCI; RGC's sparse
allgather syncs over ("pod", "data").

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run needs to set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

# jax < 0.4.38 has no explicit axis types; Auto is its only behavior, so
# omitting axis_types there is equivalent
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _make_mesh(shape, axes):
    if _AXIS_TYPE is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(_AXIS_TYPE.Auto,) * len(shape))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh over forced host devices (tests / examples)."""
    return _make_mesh((data, model), ("data", "model"))
