"""Training launcher.

On real TPU pods this runs under the production mesh; on this CPU container
it drives the same code path at smoke scale (``--smoke`` configs, optional
forced host devices via --host-devices, which must be set before jax init —
hence the env var dance at the top).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 50 --batch 8 --seq 128 --optimizer rgc --density 0.01
  REPRO_HOST_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
      --arch rwkv6-3b --smoke --mesh 4x2 --steps 20
"""
import os

if os.environ.get("REPRO_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_HOST_DEVICES"])

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, TrainConfig, get_config
from repro.data import SyntheticLM, bigram_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("paper-lstm",),
                    required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.2)
    ap.add_argument("--optimizer", default="rgc",
                    help="rgc | rgc_quant | dense | any registered "
                    "compressor spec, e.g. threshold_bsearch or "
                    "'quantized(trimmed_topk)'")
    from repro.core import registry
    ap.add_argument("--transport", default="fused_allgather",
                    choices=list(registry.names(registry.TRANSPORT)))
    ap.add_argument("--bucket-bytes", type=int, default=None,
                    help="bucketed_allgather: byte budget per fused "
                    "collective bucket (default 4 MiB)")
    ap.add_argument("--no-fuse-leaves", action="store_true",
                    help="disable the flat residual arenas (per-leaf "
                    "mask/select/pack baseline)")
    ap.add_argument("--schedule", default="sequential",
                    choices=list(registry.names(registry.SCHEDULE)),
                    help="§5.6 overlap scheduler: sequential (one "
                    "full-tree transport barrier), chunked (pipelined "
                    "per-chunk dispatch in reverse parameter order, "
                    "bitwise-identical results), stale1 (one-step-"
                    "delayed double-buffered sync)")
    ap.add_argument("--backend", default=None, choices=["jnp", "pallas"],
                    help="selection-kernel backend (pallas auto-compiles "
                    "on TPU, interprets elsewhere)")
    ap.add_argument("--density", type=float, default=0.01)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--warmup-steps-per-stage", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="DxM over host devices (e.g. 4x2); 'pod' or "
                    "'2pod' for the production meshes")
    ap.add_argument("--data", default="bigram", choices=["bigram", "zipf"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = None
    if args.mesh == "pod":
        mesh = make_production_mesh(multi_pod=False)
    elif args.mesh == "2pod":
        mesh = make_production_mesh(multi_pod=True)
    elif args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh(d, m)

    tc = TrainConfig(lr=args.lr, momentum=args.momentum,
                     optimizer=args.optimizer, transport=args.transport,
                     schedule=args.schedule, density=args.density,
                     warmup_steps_per_stage=args.warmup_steps_per_stage,
                     fuse_leaves=not args.no_fuse_leaves)
    overrides = {}
    if args.bucket_bytes is not None:
        overrides["bucket_bytes"] = args.bucket_bytes
    if args.backend is not None:
        overrides["backend"] = args.backend
    if overrides:
        import dataclasses
        tc = dataclasses.replace(tc, **overrides)
    trainer = Trainer(cfg, tc, mesh=mesh, ckpt_dir=args.ckpt_dir)
    state = trainer.init_state()
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n:,} optimizer={args.optimizer} "
          f"density={args.density} mesh={args.mesh or 'single-device'}")

    if args.data == "bigram":
        batches = bigram_batches(cfg.vocab_size, args.batch, args.seq,
                                 seed=tc.seed)
    else:
        batches = iter(SyntheticLM(cfg.vocab_size, args.batch, args.seq,
                                   seed=tc.seed))
    if cfg.family in ("vlm", "encdec"):
        # modality stubs: attach frame/patch embeddings to each batch
        from repro.models.registry import get_model
        model = get_model(cfg)
        stub = model.make_train_batch(args.batch, args.seq)

        def with_stub(src):
            for b in src:
                extra = {k: v for k, v in stub.items() if k != "tokens"}
                yield {**b, **extra}
        batches = with_stub(batches)

    trainer.run(state, batches, args.steps, log_every=args.log_every)


if __name__ == "__main__":
    main()
