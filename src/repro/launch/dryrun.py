import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) pair, lower + compile the right step
on the production mesh — 16x16 single-pod and (2,16,16) multi-pod — with
ShapeDtypeStruct stand-ins (zero allocation), then record:

  * memory_analysis()  — per-device bytes (proves it fits / flags overflow)
  * cost_analysis()    — per-device HLO FLOPs + bytes accessed
  * collective wire bytes parsed from compiled HLO (launch/hlo_stats.py)

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json, which the
roofline benchmark (benchmarks/roofline.py) consumes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]

NOTE: the XLA_FLAGS line above MUST precede any jax import — device count
locks at first init. Do not import this module from test/bench processes.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, ParallelConfig, TrainConfig, get_config
from repro.configs.shapes import InputShape
from repro.core.rgc import rgc_init
from repro.launch.hlo_stats import collective_summary
from repro.launch.mesh import make_production_mesh
from repro.models.registry import Model, get_model
from repro.train.trainer import (fsdp_parallel_config, make_fsdp_dense_step,
                                 make_rgc_config, make_train_step)
from repro.train.serve import make_decode_step, make_prefill_step

# Per-arch memory adaptations for the paper-faithful RGC train step
# (documented in EXPERIMENTS.md §Dry-run):
#   qwen3-32b    — replicated f32 residual+momentum (16 GB/chip) exceeds
#                  v5e HBM; vanilla-SGD RGC (the paper's LSTM setting) with
#                  bf16 residual fits.
#   grok-1-314b  — 314B params cannot hold ANY per-replica residual state;
#                  the paper's technique structurally requires replicated
#                  parameter storage -> dense GSPMD/FSDP baseline instead
#                  (DESIGN.md §Arch-applicability).
TRAIN_OVERRIDES: dict[str, dict] = {
    "qwen3-32b": {"momentum": 0.0, "residual_dtype": "bf16"},
    "grok-1-314b": {"optimizer": "dense_fsdp"},
}
# serve-side storage sharding: grok params don't fit 16-way model sharding
SERVE_FSDP = {"grok-1-314b"}


def _abstract_state(model: Model, params_s, tc: TrainConfig, mesh):
    rgc_cfg = make_rgc_config(tc, mesh)
    return jax.eval_shape(lambda p: rgc_init(p, rgc_cfg), params_s)


# ---------------------------------------------------------------------------
# calibration lowers (roofline accuracy)
#
# XLA's cost_analysis counts every loop body ONCE (scan over layers, the
# flash-attention kv fori_loop, the chunked-CE scan...). For the roofline we
# therefore lower additional CALIBRATION variants with (a) loops removed
# where exact (single-trip chunks) and (b) 1 vs 2 layer units with
# scan_layers=False, and extrapolate: corrected = base + trips * unit.
# benchmarks/roofline.py assembles the correction; records carry tag
# calib_<unit>_<n>.
# ---------------------------------------------------------------------------

def _loopfree(cfg, seq: int):
    """Chunk settings that make in-layer loops single-trip (exact count).

    Full attention: one q x kv block (counts the full S^2 rectangle — a
    ~2x conservative overcount vs ideal causal skipping, noted in
    EXPERIMENTS.md). SWA: q=window, kv=2*window -> one trip, ~1.33x
    overcount of the true window band.
    """
    # NOTE wkv_chunk stays at the production value: chunked-WKV cost is
    # QUADRATIC in the chunk (scores [B,H,L,L]) — chunk=seq would measure
    # O(S^2) instead of the production O(S*chunk); the once-counted wkv
    # scan body is <0.1% of a layer (the 5 D^2 projections dominate).
    kw = dict(loss_chunk=seq)
    if cfg.window_size:
        kw.update(attn_q_chunk=cfg.window_size,
                  attn_kv_chunk=2 * cfg.window_size)
    else:
        kw.update(attn_q_chunk=seq, attn_kv_chunk=seq)
    return dataclasses.replace(cfg, **kw)


def calib_variants(arch: str) -> dict[str, tuple]:
    """unit name -> (cfg_1unit, cfg_2unit, trips_in_full_config)."""
    cfg = get_config(arch)
    out: dict[str, tuple] = {}
    if cfg.family == "hybrid":
        pat = cfg.layer_pattern or ("R", "R", "L")
        counts = {c: sum(1 for i in range(cfg.num_layers)
                         if pat[i % len(pat)] == c) for c in set(pat)}
        for code, n in counts.items():
            c1 = dataclasses.replace(cfg, num_layers=1,
                                     layer_pattern=(code,),
                                     scan_layers=False)
            c2 = dataclasses.replace(cfg, num_layers=2,
                                     layer_pattern=(code, code),
                                     scan_layers=False)
            out[f"layer{code}"] = (c1, c2, n)
        return out
    if cfg.family == "encdec":
        e1 = dataclasses.replace(cfg, encoder_layers=1, num_layers=1,
                                 scan_layers=False)
        e2 = dataclasses.replace(cfg, encoder_layers=2, num_layers=1,
                                 scan_layers=False)
        d2 = dataclasses.replace(cfg, encoder_layers=1, num_layers=2,
                                 scan_layers=False)
        out["enc"] = (e1, e2, cfg.encoder_layers)
        out["dec"] = (e1, d2, cfg.num_layers)
        return out
    codes = set(cfg.pattern_codes())
    code_names = {0: "G", 1: "L"}
    for code in codes:
        n = sum(1 for c in cfg.pattern_codes() if c == code)
        pat = (code_names[code],)
        c1 = dataclasses.replace(cfg, num_layers=1, layer_pattern=pat,
                                 scan_layers=False)
        c2 = dataclasses.replace(cfg, num_layers=2, layer_pattern=pat * 2,
                                 scan_layers=False)
        out[f"layer{code_names[code]}"] = (c1, c2, n)
    return out


def lower_pair(arch: str, shape: InputShape, mesh, *,
               optimizer: str = "rgc", density: float = 0.001,
               cfg=None):
    """Build + lower the step for one (arch, shape). Returns (lowered,
    meta) or raises. Skips (returns None) out-of-family pairs."""
    cfg = cfg if cfg is not None else get_config(arch)
    model = get_model(cfg)
    pc = ParallelConfig()

    if shape.kind == "train":
        ov = dict(TRAIN_OVERRIDES.get(arch, {}))
        opt = ov.pop("optimizer", optimizer)
        tc = TrainConfig(optimizer=opt, density=density, **ov)
        params_s = model.abstract_params()
        batch_s = model.train_inputs(shape.global_batch, shape.seq_len)
        lr_s = jax.ShapeDtypeStruct((), jnp.float32)
        # donate args: matches production aliasing (params/opt state update
        # in place) — halves peak memory (qwen3 train: 15.3 -> 7.6 GiB)
        if opt == "dense_fsdp":
            step = make_fsdp_dense_step(model, mesh, pc, tc, donate=True)
            mom_s = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                params_s)
            lowered = step.lower(params_s, mom_s, batch_s, lr_s)
        else:
            step = make_train_step(model, mesh, pc, tc, donate=True)
            state_s = _abstract_state(model, params_s, tc, mesh)
            lowered = step.lower(params_s, state_s, batch_s, lr_s)
        return lowered, {"optimizer": opt, "overrides": ov}

    if shape.kind == "decode" and shape.name == "long_500k":
        if not model.supports_long:
            return None, {"skipped": "full-attention arch: long_500k decode "
                          "is out of family (DESIGN.md shape carve-outs)"}
    if model.cache_struct is None:
        return None, {"skipped": "no decode path for this family"}

    spc = fsdp_parallel_config(pc, mesh) if arch in SERVE_FSDP else pc
    params_s = model.abstract_params()
    cache_s = model.cache_struct(shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        step = make_prefill_step(model, mesh, spc, shape.global_batch,
                                 shape.seq_len)
        batch_s = model.train_inputs(shape.global_batch, shape.seq_len)
        lowered = step.lower(params_s, batch_s, cache_s)
    else:
        step = make_decode_step(model, mesh, spc, shape.global_batch,
                                shape.seq_len)
        tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos_s = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = step.lower(params_s, cache_s, tok_s, pos_s)
    return lowered, {"optimizer": "serve",
                     "fsdp_params": arch in SERVE_FSDP}


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes", "peak_memory_in_bytes")
    out = {}
    for k in keys:
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    if not out:
        out["repr"] = str(mem)
    return out


def _patch_cfg(cfg, settings: dict):
    """Apply --set key=value overrides (ints/floats/strs auto-coerced)."""
    if not settings:
        return cfg
    coerced = {}
    for k, v in settings.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            coerced[k] = v in ("1", "true", "True")
        elif isinstance(cur, int):
            coerced[k] = int(v)
        elif isinstance(cur, float):
            coerced[k] = float(v)
        else:
            coerced[k] = v
    return dataclasses.replace(cfg, **coerced)


def run_pair(arch: str, shape: InputShape, *, multi_pod: bool,
             out_dir: str, skip_existing: bool = False,
             optimizer: str = "rgc", density: float = 0.001,
             tag: str = "", settings: dict | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    fname = os.path.join(out_dir,
                         f"{arch}__{shape.name}__{mesh_name}{suffix}.json")
    if skip_existing and os.path.exists(fname):
        with open(fname) as f:
            return json.load(f)
    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                 "devices": n_dev, "optimizer": optimizer, "tag": tag}
    t0 = time.time()
    try:
        cfg = _patch_cfg(get_config(arch), settings or {})
        rec["settings"] = settings or {}
        # the mesh context makes bare-PartitionSpec activation constraints
        # (models.common.shard) bind and exposes the abstract mesh to
        # trace-time introspection (moe shard-local dispatch); without it
        # they silently no-op
        with jax.set_mesh(mesh):
            lowered, meta = lower_pair(arch, shape, mesh,
                                       optimizer=optimizer,
                                       density=density, cfg=cfg)
        rec.update(meta or {})
        if lowered is None:
            rec["status"] = "skipped"
            print(f"[skip] {arch} x {shape.name} ({mesh_name}): "
                  f"{rec.get('skipped')}")
        else:
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = _mem_dict(mem)
            cost = compiled.cost_analysis()
            rec["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals", "bytes accessed",
                          "optimal_seconds")
                    or k.startswith("bytes accessed"))}
            hlo = compiled.as_text()
            rec["collectives"] = collective_summary(hlo)
            rec["hlo_bytes"] = len(hlo)
            rec["status"] = "ok"
            print(f"[ok]   {arch} x {shape.name} ({mesh_name}) "
                  f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
                  f"flops/dev {rec['cost_analysis'].get('flops', 0):.3e} "
                  f"wire/dev {rec['collectives']['total_wire_bytes']:.3e}B")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch} x {shape.name} ({mesh_name}): {rec['error']}")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_calib(arch: str, shape: InputShape, *, multi_pod: bool,
              out_dir: str, skip_existing: bool = False,
              optimizer: str = "rgc", density: float = 0.001) -> list[dict]:
    """Calibration lowers for one (arch, shape): per layer-unit, 1- and
    2-unit loop-free variants. Only train/prefill kinds need them (decode
    paths are loop-free already)."""
    if shape.kind == "decode":
        return []
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    recs = []
    for unit, (c1, c2, trips) in calib_variants(arch).items():
        for n, ccfg in ((1, c1), (2, c2)):
            tag = f"calib_{unit}_{n}"
            fname = os.path.join(
                out_dir, f"{arch}__{shape.name}__{mesh_name}__{tag}.json")
            if skip_existing and os.path.exists(fname):
                with open(fname) as f:
                    recs.append(json.load(f))
                continue
            rec = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                   "tag": tag, "unit": unit, "units": n, "trips": trips}
            t0 = time.time()
            try:
                ccfg = _loopfree(ccfg, shape.seq_len)
                with jax.set_mesh(mesh):
                    lowered, meta = lower_pair(
                        arch, shape, mesh, optimizer=optimizer,
                        density=density, cfg=ccfg)
                if lowered is None:
                    rec["status"] = "skipped"
                else:
                    compiled = lowered.compile()
                    cost = compiled.cost_analysis()
                    rec["cost_analysis"] = {
                        k: float(v) for k, v in cost.items()
                        if isinstance(v, (int, float)) and
                        k in ("flops", "transcendentals", "bytes accessed")}
                    rec["collectives"] = collective_summary(
                        compiled.as_text())
                    rec["status"] = "ok"
                    rec["seconds"] = round(time.time() - t0, 2)
                    print(f"[calib] {arch} x {shape.name} {tag} "
                          f"flops/dev {rec['cost_analysis']['flops']:.3e} "
                          f"({rec['seconds']}s)")
            except Exception as e:
                rec["status"] = "error"
                rec["error"] = f"{type(e).__name__}: {e}"
                rec["traceback"] = traceback.format_exc()[-4000:]
                print(f"[calib FAIL] {arch} x {shape.name} {tag}: "
                      f"{rec['error']}")
            with open(fname, "w") as f:
                json.dump(rec, f, indent=1)
            recs.append(rec)
    return recs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimizer", default="rgc",
                    help="rgc | rgc_quant | dense | any registered "
                    "compressor spec (repro.core.registry)")
    ap.add_argument("--density", type=float, default=0.001)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--calib", action="store_true",
                    help="run the roofline calibration lowers instead")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="ModelConfig override for perf variants "
                    "(e.g. --set moe_impl=scatter --tag scatter)")
    args = ap.parse_args()
    settings = dict(kv.split("=", 1) for kv in getattr(args, "set"))

    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = (list(SHAPES.values()) if (args.all or not args.shape)
              else [SHAPES[args.shape]])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                if args.calib:
                    recs = run_calib(arch, shape, multi_pod=multi_pod,
                                     out_dir=args.out_dir,
                                     skip_existing=args.skip_existing,
                                     optimizer=args.optimizer,
                                     density=args.density)
                    n_fail += sum(r.get("status") == "error" for r in recs)
                else:
                    rec = run_pair(arch, shape, multi_pod=multi_pod,
                                   out_dir=args.out_dir,
                                   skip_existing=args.skip_existing,
                                   optimizer=args.optimizer,
                                   density=args.density, tag=args.tag,
                                   settings=settings)
                    n_fail += rec.get("status") == "error"
    if n_fail:
        raise SystemExit(f"{n_fail} pair(s) failed")


if __name__ == "__main__":
    main()
