"""Minimal deterministic checkpointing for pytrees.

Layout: <dir>/step_<n>/arrays.npz + tree.json. Leaves are saved flattened
with tree-path keys; restore validates structure against a template pytree
(shape + dtype) so a config/ckpt mismatch fails loudly, not silently.
Writes are atomic (tmp dir + rename) so an interrupted save never corrupts
the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np


def _to_numpy(leaf) -> np.ndarray:
    """bf16 (ml_dtypes) has no npz codec: store as a u16 bit-pattern view;
    the dtype is recorded in tree.json and reversed at restore."""
    arr = np.asarray(leaf)
    if arr.dtype.name == "bfloat16":
        return arr.view(np.uint16)
    return arr


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(kp): _to_numpy(leaf) for kp, leaf in flat}


def save(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        arrays = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        meta = {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in arrays.items()}
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump({"step": step, "leaves": meta}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, template: Any, step: int | None = None) -> Any:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, tmpl in flat:
        key = jax.tree_util.keystr(kp)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs "
                f"template {np.shape(tmpl)}")
        tmpl_dtype = np.asarray(tmpl).dtype
        if tmpl_dtype.name == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(tmpl_dtype)
        leaves.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
