"""Distributed RGC trainer (DESIGN.md §4): nested shard_map train step.

Structure of one step on a mesh with batch axes B = ("pod","data") (or
("data",)) and tensor axis "model":

  outer shard_map — manual over B, auto over "model":
      each data replica computes loss + grads on its LOCAL batch shard;
      gradients are LOCAL (un-averaged) — exactly what RGC consumes.
      GSPMD still shards the model axis inside (with_sharding_constraint).
  inner shard_map — manual over "model" (fully manual now):
      every leaf is a raw local shard; ``GradientSync.update`` (built from
      TrainConfig via the compressor/transport registry) runs the paper's
      Algorithm 4/5 per leaf: residual+momentum correction -> selection ->
      pack -> all_gather over B -> scatter-add decompress -> SGD apply.
      Small leaves take the dense psum fallback. With TP, each model-shard
      group compresses its own shard (Eq 1 with M -> M/tp).

``optimizer="dense"`` gives the paper's baseline (allreduce data
parallelism): same structure, density=1.0 sentinel -> every leaf dense.
The optimizer spec may prefix DGC corrections
("momentum+clip(threshold_bsearch)", see repro.core.correction) — they
run inside GradientSync ahead of the compressor; a "warmup" correction
owns the density schedule (Trainer.density_at defers to it).

Pure data-parallel meshes (no "model" axis — the simulated-cluster
harness, tests/harness/) take a single FULLY-manual shard_map over the
batch axes: params replicated, batch sharded, gradients local. No nested
partial-manual region, so this path also runs on legacy jax.

Single-device smoke mode (mesh=None): same code path, sync_axes=(), no
shard_map — used by CPU tests; the RGC algebra is identical with p=1.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, TrainConfig
from repro.core.gradient_sync import GradientSync, build_gradient_sync
from repro.jaxcompat import shard_map as shard_map_compat
from repro.core.rgc import RGCConfig
from repro.core.schedule import DensitySchedule
from repro.models.common import param_specs
from repro.models.registry import Model, get_model


@dataclass
class TrainState:
    params: Any
    rgc: Any                 # LeafState tree
    step: int = 0


def _batch_axes(mesh: Optional[Mesh]) -> tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a != "model")


def _residual_dtype(tc: TrainConfig):
    return jnp.bfloat16 if tc.residual_dtype == "bf16" else jnp.float32


def make_rgc_config(tc: TrainConfig, mesh: Optional[Mesh]) -> RGCConfig:
    """Legacy RGCConfig view of a TrainConfig (kept for dryrun callers)."""
    quant = tc.optimizer == "rgc_quant"
    return RGCConfig(
        density=tc.density,
        momentum=tc.momentum,
        nesterov=tc.nesterov,
        weight_decay=tc.weight_decay,
        quantize=quant,
        local_clip=tc.local_clip,
        sync_axes=_batch_axes(mesh),
        fuse_messages=tc.transport != "per_leaf_allgather",
        residual_dtype=_residual_dtype(tc),
    )


def make_gradient_sync(tc: TrainConfig, mesh: Optional[Mesh],
                       timer: Any = None) -> GradientSync:
    """Build the composed sync transform a TrainConfig describes.

    ``tc.optimizer`` may be "rgc" / "rgc_quant" / "dense" or any
    registered compressor spec (e.g. "threshold_bsearch",
    "quantized(trimmed_topk)") — see repro.core.registry.
    ``tc.transport`` picks the collective backend; ``tc.bucket_bytes`` /
    ``tc.intra_axis`` parameterize the bucketed / hierarchical backends.
    ``tc.schedule`` picks the §5.6 overlap scheduler (sequential /
    chunked / stale1 — repro.core.overlap). ``timer`` threads a
    StageTimer hook through the pipeline (eager benchmark runs); None =
    free NullTimer.
    """
    return build_gradient_sync(
        tc.optimizer,
        transport=tc.transport,
        sync_axes=_batch_axes(mesh),
        density=tc.density,
        momentum=tc.momentum,
        nesterov=tc.nesterov,
        weight_decay=tc.weight_decay,
        local_clip=tc.local_clip,
        residual_dtype=_residual_dtype(tc),
        warmup_steps_per_stage=tc.warmup_steps_per_stage,
        dense_warmup=tc.dense_warmup,
        bucket_bytes=tc.bucket_bytes,
        intra_axis=tc.intra_axis,
        fuse_leaves=tc.fuse_leaves,
        fuse_accumulate=tc.fuse_accumulate,
        schedule=tc.schedule,
        backend=tc.backend,
        timer=timer,
    )


def _leaf_state_specs(pspec: P, momentum: bool = True) -> Any:
    """LeafState specs congruent with a param's spec (scalars replicated)."""
    from repro.core.residual import LeafState
    return LeafState(pspec, pspec if momentum else P(), P(), P(), P())


def make_train_step(
    model: Model,
    mesh: Optional[Mesh],
    pc: ParallelConfig,
    tc: TrainConfig,
    *,
    density: Optional[float] = None,
    donate: bool = True,
) -> Callable:
    """Build the jitted train step: (params, rgc_state, batch, lr) ->
    (loss, new_params, new_rgc_state)."""
    cfg = model.cfg
    pc = pc or ParallelConfig()
    sync = make_gradient_sync(tc, mesh)
    dens = tc.density if density is None else density
    if tc.optimizer == "dense":
        dens = 1.0
    defs = model.param_defs()

    if mesh is None:
        def step(params, rgc_state, batch, lr):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            new_params, new_state = sync.update(
                grads, rgc_state, params, lr, density=dens)
            return loss, new_params, new_state
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    baxes = _batch_axes(mesh)

    if "model" not in mesh.axis_names:
        # Pure data-parallel mesh (the simulated-cluster harness): one
        # FULLY-manual shard_map over the batch axes — params replicated,
        # batch sharded, gradients local — with no nested partial-manual
        # region, so it also runs on legacy jax (same pattern as the
        # test_distributed "oracle" case).
        bspec = P(baxes)
        batch_struct = model.train_inputs(1, 1)   # keys only
        batch_specs = {k: bspec for k in batch_struct}

        def flat_step(params, rgc_state, batch, lr):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            new_params, new_state = sync.update(
                grads, rgc_state, params, lr, density=dens)
            return jax.lax.pmean(loss, baxes), new_params, new_state

        stepped = shard_map_compat(
            flat_step, mesh=mesh, axis_names=set(baxes),
            in_specs=(P(), P(), batch_specs, P()),
            out_specs=(P(), P(), P()),
            check_vma=False,
        )
        rep = NamedSharding(mesh, P())
        shardings_b = {k: NamedSharding(mesh, bspec) for k in batch_struct}
        return jax.jit(
            stepped,
            in_shardings=(rep, rep, shardings_b, rep),
            out_shardings=(rep, rep, rep),
            donate_argnums=(0, 1) if donate else (),
        )

    pspecs = param_specs(defs, pc, mesh)
    sspecs = jax.tree.map(
        lambda s: _leaf_state_specs(s, sync.uses_momentum_buffer), pspecs,
        is_leaf=lambda x: isinstance(x, P))
    # a double-buffered schedule (stale1) wraps the LeafState tree with
    # its pending message buffers — replicate those (prefix P() spec)
    wrap = getattr(sync.schedule, "wrap_state_specs", None)
    if wrap is not None:
        sspecs = wrap(sspecs, P())
    bspec = P(baxes)     # shard dim 0 over all batch axes

    def inner_sync(grads, params, rgc_state, lr):
        return sync.update(grads, rgc_state, params, lr, density=dens)

    def outer(params, rgc_state, batch, lr):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_state = shard_map_compat(
            inner_sync,
            axis_names={"model"},
            in_specs=(pspecs, pspecs, sspecs, P()),
            out_specs=(pspecs, sspecs),
            check_vma=False,
            fallback_mesh=mesh,
        )(grads, params, rgc_state, lr)
        return jax.lax.pmean(loss, baxes), new_params, new_state

    batch_struct = model.train_inputs(1, 1)   # keys only
    batch_specs = {k: bspec for k in batch_struct}

    # In the outer shard_map only batch axes are manual; params / state / lr
    # are replicated across them (P() prefix specs); the model axis stays
    # auto (GSPMD) — model sharding rides on the array shardings.
    stepped = shard_map_compat(
        outer, mesh=mesh, axis_names=set(baxes),
        in_specs=(P(), P(), batch_specs, P()),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )

    def build(params, rgc_state, batch, lr):
        return stepped(params, rgc_state, batch, lr)

    shardings_p = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=lambda x: isinstance(x, P))
    shardings_s = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                               is_leaf=lambda x: isinstance(x, P))
    shardings_b = {k: NamedSharding(mesh, bspec) for k in batch_struct}
    jitted = jax.jit(
        build,
        in_shardings=(shardings_p, shardings_s, shardings_b,
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P()), shardings_p, shardings_s),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted


def fsdp_parallel_config(pc: ParallelConfig, mesh: Mesh) -> ParallelConfig:
    """FSDP extension of a ParallelConfig: the d_model ("embed") dimension
    additionally shards over the batch axes, so parameters and optimizer
    state are fully sharded over the whole mesh (GSPMD inserts the
    all-gather / reduce-scatter pair)."""
    baxes = _batch_axes(mesh)
    fsdp_axis = baxes if len(baxes) > 1 else baxes[0]
    return pc.with_rule("embed", fsdp_axis)


def make_fsdp_dense_step(model: Model, mesh: Mesh, pc: ParallelConfig,
                         tc: TrainConfig, *, donate: bool = True) -> Callable:
    """Dense GSPMD/FSDP baseline step for models whose replicated residual
    state exceeds HBM (DESIGN.md §Arch-applicability: grok-1-314b).

    Pure pjit: params + momentum sharded over (batch axes x model); XLA
    auto-inserts the reduce-scatter/all-gather schedule; the optimizer is
    plain momentum SGD. RGC structurally does not apply to fully-sharded
    storage (no replicated parameter copy to sparsify against) — this IS
    the recorded finding, not a missing feature.

    Returns (loss, new_params, new_momentum); momentum state is a plain
    f32 param-shaped tree.
    """
    cfg = model.cfg
    defs = model.param_defs()
    fpc = fsdp_parallel_config(pc, mesh)
    pspecs = param_specs(defs, fpc, mesh)
    baxes = _batch_axes(mesh)

    def step(params, momentum, batch, lr):
        from repro.models.common import pure_gspmd
        with pure_gspmd():
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_m = jax.tree.map(
            lambda m, g: tc.momentum * m + g.astype(jnp.float32),
            momentum, grads)
        upd = new_m
        if tc.nesterov:
            upd = jax.tree.map(
                lambda g, m: g.astype(jnp.float32) + tc.momentum * m,
                grads, new_m)
        new_p = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
            params, upd)
        return loss, new_p, new_m

    shard_p = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
    batch_struct = model.train_inputs(1, 1)
    shard_b = {k: NamedSharding(mesh, P(baxes)) for k in batch_struct}
    return jax.jit(
        step,
        in_shardings=(shard_p, shard_p, shard_b, NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, P()), shard_p, shard_p),
        donate_argnums=(0, 1) if donate else (),
    )


class Trainer:
    """End-to-end training driver: schedule-aware step compilation,
    checkpointing, metrics."""

    def __init__(self, arch_cfg: ModelConfig, tc: TrainConfig,
                 mesh: Optional[Mesh] = None,
                 pc: Optional[ParallelConfig] = None,
                 ckpt_dir: Optional[str] = None):
        self.model = get_model(arch_cfg)
        self.cfg = arch_cfg
        self.tc = tc
        self.mesh = mesh
        self.pc = pc or ParallelConfig()
        self.ckpt_dir = ckpt_dir
        self.schedule = DensitySchedule(
            target=tc.density,
            warmup_steps_per_stage=tc.warmup_steps_per_stage,
            dense_warmup=tc.dense_warmup)
        self._sync = make_gradient_sync(tc, mesh)
        self._steps: dict[float, Callable] = {}

    def init_state(self, seed: Optional[int] = None) -> TrainState:
        params = self.model.init_params(
            self.tc.seed if seed is None else seed)
        return TrainState(params=params, rgc=self._sync.init(params), step=0)

    def density_at(self, step: int) -> float:
        """Density for this step: a ``warmup`` correction in the optimizer
        spec owns the schedule when present; otherwise the TrainConfig's
        warm-up fields drive the trainer-level DensitySchedule."""
        d = self._sync.scheduled_density(step)
        return self.schedule.density_at(step) if d is None else d

    def _step_fn(self, density: float) -> Callable:
        # "dense" compiles the same step at every density (make_train_step
        # pins dens=1.0): key the cache on the EFFECTIVE density so a
        # warm-up schedule doesn't trigger redundant recompiles
        if self.tc.optimizer == "dense":
            density = 1.0
        if density not in self._steps:
            self._steps[density] = make_train_step(
                self.model, self.mesh, self.pc, self.tc, density=density,
                donate=False)
        return self._steps[density]

    def run(self, state: TrainState, batches, num_steps: int,
            log_every: int = 10, log_fn=print,
            on_metrics: Optional[Callable[[int, float, float], None]] = None
            ) -> TrainState:
        """``on_metrics(step, density, loss)`` fires every step (forces a
        per-step device sync — metrics/convergence harness use)."""
        it = iter(batches)
        for _ in range(num_steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            density = self.density_at(state.step)
            fn = self._step_fn(density)
            loss, params, rgc_state = fn(
                state.params, state.rgc, batch, jnp.float32(self.tc.lr))
            state = TrainState(params, rgc_state, state.step + 1)
            if on_metrics is not None:
                on_metrics(state.step, density, float(loss))
            if log_every and state.step % log_every == 0:
                log_fn(f"step {state.step:5d}  density {density:.4%}  "
                       f"loss {float(loss):.4f}")
        if self.ckpt_dir:
            from repro.checkpoint import save
            save(self.ckpt_dir, state.step, state.params)
        return state
