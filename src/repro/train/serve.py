"""Serving steps: prefill (fill a KV cache from a prompt batch) and decode
(ONE token against a seq_len cache), with mesh shardings.

No gradient sync => no RGC here; these are plain jit with in/out shardings.

Sharding policy (adaptive, per tensor):
  * batch dim shards over the batch axes when divisible (decode_32k: 128
    over 16/32); batch=1 long-context shapes replicate it.
  * the model axis lands on the kv-head / lru / state dim when divisible,
    else on the sequence dim of the KV cache (grok kv=8 < 16-way model axis
    -> 32k cache seq shards over model; this is the TPU sequence-parallel
    KV layout).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.registry import Model


def _batch_axes(mesh: Optional[Mesh]) -> tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fits(dim: int, k: int) -> bool:
    return k > 1 and dim % k == 0


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Leading-dim batch sharding if divisible, else replicated."""
    baxes = _batch_axes(mesh)
    sizes = _axis_sizes(mesh)
    bsize = math.prod(sizes[a] for a in baxes) if baxes else 1
    return P(baxes) if _fits(batch, bsize) else P()


def _cache_leaf_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    baxes = _batch_axes(mesh)
    sizes = _axis_sizes(mesh)
    bsize = math.prod(sizes[a] for a in baxes) if baxes else 1
    msize = sizes.get("model", 1)
    spec: list[Any] = [None] * len(shape)
    if shape and _fits(shape[0], bsize):
        spec[0] = baxes
    if len(shape) == 4:                       # [B,C,Hkv,hd] or [B,H,dk,dv]
        if _fits(shape[2], msize):
            spec[2] = "model"
        elif _fits(shape[1], msize):
            spec[1] = "model"
    elif len(shape) == 3 and _fits(shape[2], msize):   # [B,cw-1,lru]
        spec[2] = "model"
    elif len(shape) == 2 and _fits(shape[1], msize):   # [B,lru] / lstm h,c
        spec[1] = "model"
    return P(*spec)


def cache_shardings(model: Model, mesh: Mesh, batch: int, seq_len: int):
    struct = model.cache_struct(batch, seq_len)
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, _cache_leaf_spec(leaf.shape, mesh)),
        struct)


def _gspmd_auto(fn):
    """Trace with activation constraints disabled (GSPMD propagates from
    the in/out shardings; see models.common.no_activation_constraints)."""
    from repro.models.common import no_activation_constraints

    def wrapped(*args):
        with no_activation_constraints():
            return fn(*args)
    return wrapped


def make_prefill_step(model: Model, mesh: Optional[Mesh],
                      pc: ParallelConfig, batch: int, seq_len: int):
    """jitted (params, batch, cache) -> (cache, last-token logits)."""
    if mesh is None:
        return jax.jit(model.prefill)
    bshard = NamedSharding(mesh, batch_spec(mesh, batch))
    cshard = cache_shardings(model, mesh, batch, seq_len)
    batch_shardings = {k: bshard for k in model.train_inputs(1, 1)}
    from repro.models.common import param_specs
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          param_specs(model.param_defs(), pc, mesh),
                          is_leaf=lambda x: isinstance(x, P))
    return jax.jit(
        _gspmd_auto(model.prefill),
        in_shardings=(pshard, batch_shardings, cshard),
        out_shardings=(cshard, bshard),
        donate_argnums=(2,),          # cache updated in place
    )


def make_decode_step(model: Model, mesh: Optional[Mesh],
                     pc: ParallelConfig, batch: int, seq_len: int):
    """jitted (params, cache, token, pos) -> (logits, cache)."""
    if mesh is None:
        return jax.jit(model.decode_step)
    bshard = NamedSharding(mesh, batch_spec(mesh, batch))
    cshard = cache_shardings(model, mesh, batch, seq_len)
    from repro.models.common import param_specs
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          param_specs(model.param_defs(), pc, mesh),
                          is_leaf=lambda x: isinstance(x, P))
    return jax.jit(
        _gspmd_auto(model.decode_step),
        in_shardings=(pshard, cshard, bshard, NamedSharding(mesh, P())),
        out_shardings=(bshard, cshard),
        donate_argnums=(1,),          # cache updated in place
    )


class ServeLoop:
    """Minimal batched-request serving driver (greedy decode)."""

    def __init__(self, model: Model, mesh: Optional[Mesh] = None,
                 pc: Optional[ParallelConfig] = None, *, batch: int,
                 max_len: int):
        self.model = model
        self.batch, self.max_len = batch, max_len
        self.prefill = make_prefill_step(model, mesh, pc or ParallelConfig(),
                                         batch, max_len)
        self.decode = make_decode_step(model, mesh, pc or ParallelConfig(),
                                       batch, max_len)

    def generate(self, params, prompt_batch: dict, num_tokens: int):
        prompt_len = prompt_batch["tokens"].shape[1]
        cache = self.model.init_cache(self.batch, self.max_len)
        cache, logits = self.prefill(params, prompt_batch, cache)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [tok]
        for i in range(num_tokens - 1):
            logits, cache = self.decode(params, cache, tok,
                                        jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
