from .trainer import TrainState, Trainer, make_train_step
from .serve import make_decode_step, make_prefill_step
