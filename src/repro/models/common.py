"""Shared model substrate: param definitions, init, sharding specs, norms,
RoPE, MLPs, embeddings, chunked cross-entropy.

Parameters are plain nested dicts. Structure is declared once as a tree of
``ParamDef`` (shape + logical axes + initializer); the same tree drives
materialized init, abstract ShapeDtypeStructs for the dry-run, and
PartitionSpec resolution (logical axes -> mesh axes with divisibility
guards). Activation sharding uses ``with_sharding_constraint`` over the
GSPMD-auto ``model`` axis only — batch axes are manual (shard_map) in the
trainer, see DESIGN.md §4.
"""
from __future__ import annotations

import hashlib
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig


class ParamDef(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]      # logical axis names, len == ndim
    init: str = "normal"                 # normal | zeros | ones | embed
    scale: float = 1.0                   # stddev multiplier for "normal"


def _path_key(seed_key: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.md5(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(seed_key, h)


def init_params(defs: Any, seed: int, dtype: Any) -> Any:
    """Materialize a ParamDef tree into concrete arrays."""
    root = jax.random.PRNGKey(seed)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    leaves = []
    for kp, d in flat:
        path = jax.tree_util.keystr(kp)
        if d.init == "zeros":
            leaves.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            leaves.append(jnp.ones(d.shape, dtype))
        else:
            fan_in = d.shape[0] if d.shape else 1
            std = d.scale / max(fan_in, 1) ** 0.5
            if d.init == "embed":
                std = d.scale
            k = _path_key(root, path)
            leaves.append((jax.random.normal(k, d.shape, jnp.float32)
                           * std).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(defs: Any, dtype: Any, mesh=None, pc: ParallelConfig | None = None) -> Any:
    """ShapeDtypeStruct tree (with shardings if mesh given) for .lower()."""
    specs = param_specs(defs, pc or ParallelConfig(), mesh) if mesh is not None else None

    def mk(d: ParamDef, spec):
        sharding = NamedSharding(mesh, spec) if mesh is not None else None
        return jax.ShapeDtypeStruct(d.shape, dtype, sharding=sharding)

    if specs is None:
        return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
                            defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return jax.tree.map(mk, defs, specs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def param_specs(defs: Any, pc: ParallelConfig, mesh) -> Any:
    """Resolve logical axes -> PartitionSpec with divisibility guards.

    A rule value may be a single mesh axis or a tuple of axes (FSDP-style
    joint sharding, e.g. ("pod", "data")); tuples require divisibility by
    the product of their sizes.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}

    def _size(mesh_axis) -> int | None:
        axes = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        n = 1
        for a in axes:
            if a not in axis_sizes:
                return None
            n *= axis_sizes[a]
        return n

    def resolve(d: ParamDef) -> P:
        out = []
        used: set = set()
        for dim, logical in zip(d.shape, d.axes):
            mesh_axis = pc.rule(logical) if logical else None
            size = _size(mesh_axis) if mesh_axis is not None else None
            flat = (set(mesh_axis) if isinstance(mesh_axis, tuple)
                    else {mesh_axis})
            if (mesh_axis is None or size is None or (flat & used)
                    or dim % size != 0):
                out.append(None)
            else:
                out.append(mesh_axis)
                used |= flat
        return P(*out)

    return jax.tree.map(resolve, defs, is_leaf=lambda x: isinstance(x, ParamDef))


import contextlib
import contextvars

_SHARD_OFF = contextvars.ContextVar("repro_shard_constraints_off",
                                    default=False)
_STRUCT_OFF = contextvars.ContextVar("repro_structural_shardmap_off",
                                     default=False)


@contextlib.contextmanager
def pure_gspmd():
    """Disable BOTH activation constraints and structural shard_map
    wrapping (moe shard-local dispatch) for the enclosed trace. Used by
    the FSDP dense step: a nested shard_map over the data axis inside a
    pjit whose params are data-sharded trips an XLA:CPU partitioner
    crash (Invalid binary instruction opcode copy) and would all-gather
    the full parameter tree anyway."""
    t1 = _SHARD_OFF.set(True)
    t2 = _STRUCT_OFF.set(True)
    try:
        yield
    finally:
        _STRUCT_OFF.reset(t2)
        _SHARD_OFF.reset(t1)


def structural_shardmap_enabled() -> bool:
    return not _STRUCT_OFF.get()


@contextlib.contextmanager
def no_activation_constraints():
    """Disable shard() constraints for the enclosed trace.

    Serve steps (plain jit) trace under this: GSPMD propagates layouts
    from the in/out shardings better than the hand constraints, which are
    written for the trainer's manual-data region (measured: gemma3
    prefill wire 6.2e10 auto vs 1.5e11 constrained — §Perf it.5)."""
    tok = _SHARD_OFF.set(True)
    try:
        yield
    finally:
        _SHARD_OFF.reset(tok)


def shard(x: jax.Array, *spec) -> jax.Array:
    """Constraint over the auto 'model' axis; no-op outside jit/mesh context
    and under ``no_activation_constraints()``.

    ``None`` dims are UNCONSTRAINED, not replicated: the same model code
    runs inside the trainer's manual-data region (batch dims local) AND in
    auto-sharded serving (batch dims sharded over data) — pinning batch
    dims to replicated would force per-layer activation gathers in serving
    (measured 29x extra prefill FLOPs, EXPERIMENTS.md §Perf it.4).
    """
    if _SHARD_OFF.get():
        return x
    full = P(*[P.UNCONSTRAINED if s is None else s for s in spec])
    try:
        return jax.lax.with_sharding_constraint(x, full)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] i32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # [..., S, 1, half]: broadcast over the heads dim
    ang = positions.astype(jnp.float32)[..., :, None, None] * freq[None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)  # [..., S, 1, half]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated) + embedding / loss
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig) -> dict:
    return {
        "w_gate": ParamDef((cfg.d_model, cfg.d_ff), ("embed", "ffn")),
        "w_up": ParamDef((cfg.d_model, cfg.d_ff), ("embed", "ffn")),
        "w_down": ParamDef((cfg.d_ff, cfg.d_model), ("ffn", "embed")),
    }


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    g = shard(x @ p["w_gate"], None, None, "model")
    u = shard(x @ p["w_up"], None, None, "model")
    h = act_fn(cfg.act)(g) * u
    return shard(h @ p["w_down"], None, None, None)


def embed_defs(cfg: ModelConfig) -> dict:
    d = {"table": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        d["lm_head"] = ParamDef((cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), init="embed", scale=0.02)
    return d


def embed_lookup(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return x


def lm_logits(cfg: ModelConfig, p: dict, h: jax.Array) -> jax.Array:
    """[..., D] -> [..., V], vocab-sharded."""
    table = p["lm_head"] if "lm_head" in p else p["table"]
    return shard(h @ table.T, None, None, "model")


def chunked_ce_loss(cfg: ModelConfig, embed_p: dict, hidden: jax.Array,
                    labels: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token CE without materializing [B,S,V] logits.

    hidden: [B,S,D]; labels: [B,S] i32 (targets aligned with hidden);
    mask: [B,S] f32 weights (None = all ones). Scans over sequence chunks;
    each chunk's logits are vocab-sharded and remat'd.
    """
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk, s)
    n_chunk = -(-s // chunk)
    pad = n_chunk * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None else jnp.ones((b, s)),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, s))
    hs = hidden.reshape(b, n_chunk, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunk, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, n_chunk, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        h_c, l_c, m_c = xs
        logits = lm_logits(cfg, embed_p, h_c).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m_c
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(m_c)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
