"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with token-shift
data-dependent linear interpolation (ddlerp) and data-dependent decay.

Recurrence per head (d_k = d_v = head_dim):

    S_t   = diag(w_t) S_{t-1} + k_t^T v_t                    (state [dk, dv])
    out_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

TPU-native chunked-parallel form for train/prefill (the recurrent scan is a
degenerate GPU port — one tiny matmul per token): within a chunk of length L,
with P_t = prod_{s<=t} w_t (computed as exp(cumsum(log w)) in f32),

    rt~ = r_t * P_{t-1}        kt~ = k_t / P_t
    out = tril_strict(rt~ kt~^T) V + diag(r_t·u·k_t) V + rt~ S_0
    S_L[a,b] = P_L[a] * (S_0[a,b] + sum_j kt~_j[a] v_j[b])

so each chunk is three MXU matmuls + elementwise decay algebra; chunks chain
through ``lax.scan`` carrying S. Decode runs the exact recurrence (one step).

State carried between calls (the "KV cache" analogue, O(1) in sequence):
    s      [B, H, dk, dv]   wkv state
    x_tm   [B, D]           last input to time-mix token shift
    x_cm   [B, D]           last input to channel-mix token shift
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import (ParamDef, act_fn, chunked_ce_loss, embed_defs,
                     embed_lookup, lm_logits, layer_norm, shard)

MIX_NAMES = ("w", "k", "v", "r", "g")


def _tm_defs(cfg: ModelConfig) -> dict:
    d, lo = cfg.d_model, cfg.lora_dim
    h = cfg.d_model // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    defs = {
        # ddlerp: shared A, per-target B (the paper's stacked low-rank)
        "mix_base": ParamDef((5, d), (None, "embed"), init="zeros"),
        "mix_w1": ParamDef((d, 5 * lo), ("embed", None), scale=0.1),
        "mix_w2": ParamDef((5, lo, d), (None, None, "embed"), scale=0.1),
        # data-dependent decay lora (per-channel)
        "decay_base": ParamDef((d,), ("embed",), init="zeros"),
        "decay_w1": ParamDef((d, 2 * lo), ("embed", None), scale=0.1),
        "decay_w2": ParamDef((2 * lo, d), (None, "embed"), scale=0.1),
        "bonus": ParamDef((h, hd), ("heads", None), scale=0.1),
        "wr": ParamDef((d, d), ("embed", "heads")),
        "wk": ParamDef((d, d), ("embed", "heads")),
        "wv": ParamDef((d, d), ("embed", "heads")),
        "wg": ParamDef((d, d), ("embed", "heads")),
        "wo": ParamDef((d, d), ("heads", "embed")),
        "ln_x": ParamDef((d,), (None,), init="ones"),
        "ln_x_b": ParamDef((d,), (None,), init="zeros"),
    }
    return defs


def _cm_defs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mix_k": ParamDef((d,), ("embed",), init="zeros"),
        "mix_r": ParamDef((d,), ("embed",), init="zeros"),
        "wk": ParamDef((d, f), ("embed", "ffn")),
        "wr": ParamDef((d, d), ("embed", None), scale=0.5),
        "wv": ParamDef((f, d), ("ffn", "embed")),
    }


def layer_defs(cfg: ModelConfig) -> dict:
    return {
        "tm": _tm_defs(cfg),
        "cm": _cm_defs(cfg),
        "norm_tm": ParamDef((cfg.d_model,), (None,), init="ones"),
        "norm_tm_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "norm_cm": ParamDef((cfg.d_model,), (None,), init="ones"),
        "norm_cm_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }


def param_defs(cfg: ModelConfig) -> dict:
    from .transformer import _stack
    return {
        "embed": embed_defs(cfg),
        "ln_in": ParamDef((cfg.d_model,), (None,), init="ones"),
        "ln_in_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "layers": _stack(layer_defs(cfg), cfg.num_layers),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones"),
        "final_norm_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }


# ---------------------------------------------------------------------------
# time mix
# ---------------------------------------------------------------------------

def _ddlerp(p: dict, x: jax.Array, x_prev: jax.Array):
    """Data-dependent lerp producing the 5 mixed inputs [..., 5, D]."""
    dx = x_prev - x                                     # [B,S,D]
    base = x + dx * p["mix_base"][0]                    # the shared-A input
    lo = p["mix_w1"].shape[1] // 5
    a = jnp.tanh(base @ p["mix_w1"])                    # [B,S,5*lo]
    a = a.reshape(*a.shape[:-1], 5, lo)
    delta = jnp.einsum("bsml,mld->bsmd", a, p["mix_w2"])  # [B,S,5,D]
    mixed = x[..., None, :] + dx[..., None, :] * (
        p["mix_base"][None, None] + delta)
    return mixed                                        # order: w,k,v,r,g


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Per-channel decay in (0,1): w = exp(-exp(dd))."""
    dd = p["decay_base"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    return jnp.exp(-jnp.exp(dd.astype(jnp.float32) - 0.5))


def _wkv_chunk(r, k, v, w, u, s0):
    """One chunk. r/k/v/w: [B,H,L,hd] f32; u: [H,hd]; s0: [B,H,hd,hd].
    Returns (out [B,H,L,hd], s_end)."""
    lw = jnp.log(jnp.maximum(w, 1e-12))
    cum = jnp.cumsum(lw, axis=2)                        # log P_t
    p_full = jnp.exp(cum)                               # P_t
    p_prev = jnp.exp(cum - lw)                          # P_{t-1}
    r_t = r * p_prev
    k_t = k * jnp.exp(-cum)                             # k / P_t
    # intra-chunk scores [B,H,L,L], strictly lower triangular
    scores = jnp.einsum("bhld,bhmd->bhlm", r_t, k_t)
    ll = r.shape[2]
    tri = jnp.tril(jnp.ones((ll, ll), jnp.float32), k=-1)
    out = jnp.einsum("bhlm,bhmd->bhld", scores * tri, v)
    # current-token bonus
    diag = jnp.einsum("bhld,hd,bhld->bhl", r, u, k)
    out = out + diag[..., None] * v
    # state input
    out = out + jnp.einsum("bhli,bhij->bhlj", r_t, s0)
    # end-of-chunk state
    s_in = jnp.einsum("bhli,bhlj->bhij", k_t, v)
    s_end = p_full[:, :, -1, :, None] * (s0 + s_in)
    return out, s_end


def time_mix(cfg: ModelConfig, p: dict, x: jax.Array, x_prev_last: jax.Array,
             s0: jax.Array, *, chunk: Optional[int] = None):
    """x: [B,S,D]. x_prev_last: [B,D] final token of previous call.
    Returns (out [B,S,D], new x_last [B,D], new state)."""
    b, s, d = x.shape
    h, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    xf = x.astype(jnp.float32)
    x_shift = jnp.concatenate([x_prev_last[:, None].astype(jnp.float32),
                               xf[:, :-1]], axis=1)
    mixed = _ddlerp(p, xf, x_shift)                     # [B,S,5,D]
    xw, xk, xv, xr, xg = (mixed[:, :, i] for i in range(5))

    w = _decay(p, xw)                                   # [B,S,D] in (0,1)
    r = (xr.astype(x.dtype) @ p["wr"]).astype(jnp.float32)
    k = (xk.astype(x.dtype) @ p["wk"]).astype(jnp.float32)
    v = (xv.astype(x.dtype) @ p["wv"]).astype(jnp.float32)
    g = jax.nn.silu(xg.astype(x.dtype) @ p["wg"])

    def heads(t):                                        # [B,S,D]->[B,H,S,hd]
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    r, k, v, w = heads(r), heads(k), heads(v), heads(w)
    u = p["bonus"].astype(jnp.float32)

    ch = min(chunk or cfg.wkv_chunk, s)
    n = -(-s // ch)
    pad = n * ch - s
    if pad:
        r = jnp.pad(r, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, pad), (0, 0)),
                    constant_values=1.0)

    def split(t):                                        # [B,H,n*ch,hd]
        return t.reshape(b, h, n, ch, hd).transpose(2, 0, 1, 3, 4)

    rs, ks, vs, ws = split(r), split(k), split(v), split(w)

    def body(s_c, xs):
        r_c, k_c, v_c, w_c = xs
        out_c, s_n = _wkv_chunk(r_c, k_c, v_c, w_c, u, s_c)
        return s_n, out_c

    s_end, outs = jax.lax.scan(body, s0.astype(jnp.float32),
                               (rs, ks, vs, ws))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, n * ch, hd)[:, :, :s]
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    out = layer_norm(out.astype(x.dtype), p["ln_x"], p["ln_x_b"], cfg.norm_eps)
    out = (out * g).astype(x.dtype) @ p["wo"]
    return shard(out, None, None, None), xf[:, -1].astype(x.dtype), s_end


def time_mix_decode(cfg: ModelConfig, p: dict, x: jax.Array,
                    x_prev: jax.Array, s0: jax.Array):
    """Exact one-step recurrence. x: [B,1,D]."""
    b, _, d = x.shape
    h, hd = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    xf = x.astype(jnp.float32)
    mixed = _ddlerp(p, xf, x_prev[:, None].astype(jnp.float32))
    xw, xk, xv, xr, xg = (mixed[:, 0, i] for i in range(5))  # [B,D]
    w = _decay(p, xw).reshape(b, h, hd)
    r = (xr.astype(x.dtype) @ p["wr"]).astype(jnp.float32).reshape(b, h, hd)
    k = (xk.astype(x.dtype) @ p["wk"]).astype(jnp.float32).reshape(b, h, hd)
    v = (xv.astype(x.dtype) @ p["wv"]).astype(jnp.float32).reshape(b, h, hd)
    g = jax.nn.silu(xg.astype(x.dtype) @ p["wg"])
    u = p["bonus"].astype(jnp.float32)
    kv = k[..., :, None] * v[..., None, :]              # [B,H,dk,dv]
    out = jnp.einsum("bhi,bhij->bhj", r, s0 + u[None, :, :, None] * kv)
    s_new = w[..., :, None] * s0 + kv
    out = out.reshape(b, 1, d)
    out = layer_norm(out.astype(x.dtype), p["ln_x"], p["ln_x_b"], cfg.norm_eps)
    out = (out * g[:, None]).astype(x.dtype) @ p["wo"]
    return out, xf[:, -1].astype(x.dtype), s_new


def channel_mix(cfg: ModelConfig, p: dict, x: jax.Array, x_prev_last: jax.Array):
    xf = x.astype(jnp.float32)
    x_shift = jnp.concatenate([x_prev_last[:, None].astype(jnp.float32),
                               xf[:, :-1]], axis=1)
    dx = x_shift - xf
    xk = (xf + dx * p["mix_k"]).astype(x.dtype)
    xr = (xf + dx * p["mix_r"]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(shard(xk @ p["wk"], None, None, "model")))
    rr = jax.nn.sigmoid(xr @ p["wr"])
    out = rr * shard(kk @ p["wv"], None, None, None)
    return out.astype(x.dtype), xf[:, -1].astype(x.dtype)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h = cfg.d_model // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    z = lambda *sh: jnp.zeros(sh, jnp.float32)
    return tuple(
        {"s": z(batch, h, hd, hd),
         "x_tm": z(batch, cfg.d_model), "x_cm": z(batch, cfg.d_model)}
        for _ in range(cfg.num_layers))


def state_struct(cfg: ModelConfig, batch: int):
    h = cfg.d_model // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    f = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.float32)
    return tuple(
        {"s": f(batch, h, hd, hd),
         "x_tm": f(batch, cfg.d_model), "x_cm": f(batch, cfg.d_model)}
        for _ in range(cfg.num_layers))


def _layer(cfg: ModelConfig, lp: dict, x, st, *, decode: bool):
    h = layer_norm(x, lp["norm_tm"], lp["norm_tm_b"], cfg.norm_eps)
    if decode:
        a, x_tm, s_new = time_mix_decode(cfg, lp["tm"], h, st["x_tm"], st["s"])
    else:
        a, x_tm, s_new = time_mix(cfg, lp["tm"], h, st["x_tm"], st["s"])
    x = x + a
    h = layer_norm(x, lp["norm_cm"], lp["norm_cm_b"], cfg.norm_eps)
    c, x_cm = channel_mix(cfg, lp["cm"], h, st["x_cm"])
    return x + c, {"s": s_new, "x_tm": x_tm, "x_cm": x_cm}


def _run(cfg: ModelConfig, params: dict, x: jax.Array, states, *,
         decode: bool = False):
    if cfg.scan_layers and not decode:
        # stack the per-layer states for a layer scan
        st = jax.tree.map(lambda *xs: jnp.stack(xs), *states)

        def body(carry, xs):
            lp, s_i = xs
            y, s_n = _layer(cfg, lp, carry, s_i, decode=False)
            return y, s_n

        if cfg.remat:
            body = jax.checkpoint(body)
        x, st_new = jax.lax.scan(body, x, (params["layers"], st))
        n = cfg.num_layers
        new_states = tuple(jax.tree.map(lambda a, i=i: a[i], st_new)
                           for i in range(n))
        return x, new_states
    new_states = []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        x, s_n = _layer(cfg, lp, x, states[i], decode=decode)
        new_states.append(s_n)
    return x, tuple(new_states)


def _embed(cfg, params, tokens):
    x = embed_lookup(cfg, params["embed"], tokens)
    return layer_norm(x, params["ln_in"], params["ln_in_b"], cfg.norm_eps)


def loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    states = init_state(cfg, tokens.shape[0])
    x, _ = _run(cfg, params, x, states)
    h = layer_norm(x, params["final_norm"], params["final_norm_b"],
                   cfg.norm_eps)
    return chunked_ce_loss(cfg, params["embed"], h[:, :-1], tokens[:, 1:],
                           batch.get("loss_mask"))


def prefill(cfg: ModelConfig, params: dict, batch: dict, states):
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    x, states = _run(cfg, params, x, states)
    h = layer_norm(x[:, -1:], params["final_norm"], params["final_norm_b"],
                   cfg.norm_eps)
    return states, lm_logits(cfg, params["embed"], h)


def decode_step(cfg: ModelConfig, params: dict, states, token: jax.Array,
                pos: jax.Array):
    x = _embed(cfg, params, token)
    x, states = _run(cfg, params, x, states, decode=True)
    h = layer_norm(x, params["final_norm"], params["final_norm_b"],
                   cfg.norm_eps)
    return lm_logits(cfg, params["embed"], h), states
