"""Attention: GQA/MQA, RoPE, qk-norm, sliding-window, prefix-LM, cross-attn.

Train/prefill use a flash-style chunked implementation (pure JAX): an outer
``lax.map`` over query chunks and an inner ``fori_loop`` with *dynamic* kv
bounds doing online softmax — full [S,S] score tensors are never
materialized, and causal/window structure skips out-of-span kv chunks
entirely (not just masks them). Decode attends a single query against the
KV cache (linear cache for full attention, ring buffer for SWA layers).

Head layout: q [B,S,Hkv,G,hd] grouped per kv head; k/v [B,S,Hkv,hd].
Sharding constraints are applied on the flattened [B,S,H*hd] projections
(model axis); GSPMD propagates through the reshapes.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import ParamDef, rms_norm, rope, shard

NEG_INF = -1e30


def attn_defs(cfg: ModelConfig) -> dict:
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    d = {
        "wq": ParamDef((cfg.d_model, hq * hd), ("embed", "heads")),
        "wk": ParamDef((cfg.d_model, hkv * hd), ("embed", "kv_heads")),
        "wv": ParamDef((cfg.d_model, hkv * hd), ("embed", "kv_heads")),
        "wo": ParamDef((hq * hd, cfg.d_model), ("heads", "embed")),
    }
    if cfg.qk_norm:
        d["q_norm"] = ParamDef((hd,), (None,), init="zeros")
        d["k_norm"] = ParamDef((hd,), (None,), init="zeros")
    return d


class KVCache(NamedTuple):
    k: jax.Array          # [B, C, Hkv, hd] — C = seq_len or window (ring)
    v: jax.Array
    ring: bool            # static python bool via cache_spec construction


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int,
               window: Optional[int]) -> tuple[tuple[int, ...], bool]:
    c = min(window, seq_len) if window else seq_len
    return (batch, c, cfg.num_kv_heads, cfg.head_dim), bool(window)


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array,
                 positions: jax.Array, theta: float):
    b, s, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    q = shard(x @ p["wq"], None, None, "model").reshape(b, s, hq, hd)
    k = shard(x @ p["wk"], None, None, None).reshape(b, s, hkv, hd)
    v = shard(x @ p["wv"], None, None, None).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    return q, k, v


def _mask(pos_q, pos_k, *, causal, window, prefix_len, kv_limit):
    """[qc, kc] boolean mask from absolute positions."""
    m = pos_k[None, :] < kv_limit
    if causal:
        c = pos_k[None, :] <= pos_q[:, None]
        if window is not None:
            c = c & (pos_k[None, :] > pos_q[:, None] - window)
        if prefix_len is not None:
            both_prefix = (pos_q[:, None] < prefix_len) & (pos_k[None, :] < prefix_len)
            c = c | both_prefix
        m = m & c
    return m


def flash_attention(
    q: jax.Array,             # [B, Sq, Hq, hd]
    k: jax.Array,             # [B, Skv, Hkv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: Optional[int] = None,       # STATIC (trace-time) prefix span
    q_offset: int = 0,        # absolute position of q[0] (== 0 for self-attn)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    softcap: Optional[float] = None,
    differentiable: bool = True,
) -> jax.Array:
    """Chunked online-softmax attention.

    ``differentiable=True`` (training): the q-chunk loop is a static Python
    unroll so each chunk's kv span [lo_c, hi_c) is a *static* interval —
    out-of-span kv chunks are skipped structurally (not masked), keeping
    causal/SWA FLOPs at the true count, and static bounds keep the inner
    ``fori_loop`` reverse-differentiable.

    ``differentiable=False`` (serving prefill): the q loop is a traced
    ``lax.map`` with dynamic kv bounds — same math, flat HLO (a 32k prefill
    over 64 q-chunks x 32 layers would otherwise explode compile time).
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = hd ** -0.5

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    nq, nk = -(-sq // qc), -(-skv // kc)
    qp = jnp.pad(q, ((0, 0), (0, nq * qc - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - skv), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq * qc, hkv, g, hd)
    kv_true = jnp.int32(skv)

    def q_body(qi):
        static = isinstance(qi, int)
        qs = qi * qc
        if static:
            q_blk = jax.lax.slice_in_dim(qp, qs, qs + qc, axis=1)
        else:
            q_blk = jax.lax.dynamic_slice_in_dim(qp, qs, qc, axis=1)
        q_blk = q_blk.astype(jnp.float32) * scale
        pos_q = q_offset + qs + jnp.arange(qc)

        # kv-chunk span for this q chunk (static ints on the training path)
        if not causal:
            lo_c, hi_c = 0, nk
        elif static:
            hi = min(q_offset + qs + qc, skv)
            if prefix_len is not None:
                hi = max(hi, int(prefix_len))
            hi_c = min(-(-hi // kc), nk)
            if window is None or prefix_len is not None:
                lo_c = 0
            else:
                lo_c = max(0, (q_offset + qs - window) // kc)
        else:
            hi = jnp.minimum(q_offset + qs + qc, skv)
            if prefix_len is not None:
                hi = jnp.maximum(hi, int(prefix_len))
            hi_c = jnp.minimum(-(-hi // kc), nk).astype(jnp.int32)
            if window is None or prefix_len is not None:
                lo_c = jnp.int32(0)
            else:
                lo_c = jnp.maximum(
                    0, (q_offset + qs - window) // kc).astype(jnp.int32)

        acc0 = jnp.zeros((b, hkv, g, qc, hd), jnp.float32)
        m0 = jnp.full((b, hkv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)

        def kv_body(j, carry):
            acc, m, l = carry
            ks = j * kc
            k_blk = jax.lax.dynamic_slice_in_dim(kp, ks, kc, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(vp, ks, kc, axis=1)
            pos_k = ks + jnp.arange(kc)
            s_blk = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk,
                               k_blk.astype(jnp.float32))
            if softcap is not None:
                s_blk = softcap * jnp.tanh(s_blk / softcap)
            msk = _mask(pos_q, pos_k, causal=causal, window=window,
                        prefix_len=prefix_len, kv_limit=kv_true)
            s_blk = jnp.where(msk[None, None, None], s_blk, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
            p_blk = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p_blk, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p_blk, v_blk.astype(jnp.float32))
            return acc_new, m_new, l_new

        acc, m, l = jax.lax.fori_loop(lo_c, hi_c, kv_body, (acc0, m0, l0))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)        # [B, qc, Hkv, G, hd]

    if differentiable:
        out = jnp.concatenate([q_body(i) for i in range(nq)], axis=1)
    else:
        out = jax.lax.map(q_body, jnp.arange(nq))   # [nq, B, qc, hkv, g, hd]
        out = out.transpose(1, 0, 2, 3, 4, 5)
    out = out.reshape(b, nq * qc, hq, hd)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,             # [B, 1, Hq, hd]
    cache: KVCache,
    pos: jax.Array,           # scalar i32: index of the token being decoded
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    b, _, hq, hd = q.shape
    c, hkv = cache.k.shape[1], cache.k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, hd).astype(jnp.float32) * hd ** -0.5
    kf = cache.k.astype(jnp.float32)
    s = jnp.einsum("bhgd,bchd->bhgc", qf, kf)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.arange(c) <= (pos if not window else jnp.minimum(pos, c - 1))
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgc,bchd->bhgd", p, cache.v.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def cache_insert(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array) -> KVCache:
    """Insert one decode step's k/v ([B,1,Hkv,hd]) at pos (ring-aware)."""
    c = cache.k.shape[1]
    slot = jnp.remainder(pos, c) if cache.ring else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype),
                                            slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype),
                                            slot, axis=1)
    return KVCache(k, v, cache.ring)


class AttnOut(NamedTuple):
    out: jax.Array
    cache: Optional[KVCache]


def attention_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array,
    theta: float,
    window: Optional[int] = None,
    causal: bool = True,
    prefix_len: Optional[jax.Array] = None,
    cache: Optional[KVCache] = None,
    decode_pos: Optional[jax.Array] = None,
    fill_cache: bool = False,
    softcap: Optional[float] = None,
    differentiable: bool = True,
) -> AttnOut:
    """Unified self-attention: train (no cache), prefill (fill_cache=True),
    decode (cache + decode_pos)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions, theta)

    if cache is not None and decode_pos is not None:      # decode
        cache = cache_insert(cache, k, v, decode_pos)
        out = decode_attention(q, cache, decode_pos, window=window,
                               softcap=softcap)
    else:                                                 # train / prefill
        out = flash_attention(
            q, k, v, causal=causal, window=window, prefix_len=prefix_len,
            q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
            softcap=softcap, differentiable=differentiable)
        if fill_cache and cache is not None:
            c = cache.k.shape[1]
            if cache.ring:
                # keep the last `c` positions (prefill longer than window)
                start = jnp.maximum(0, s - c)
                k_tail = jax.lax.dynamic_slice_in_dim(k, start, min(c, s), 1)
                v_tail = jax.lax.dynamic_slice_in_dim(v, start, min(c, s), 1)
                # ring layout: slot = pos % c; for pos = start..start+c-1
                slots = jnp.remainder(start + jnp.arange(min(c, s)), c)
                kc_ = cache.k.at[:, slots].set(k_tail.astype(cache.k.dtype))
                vc_ = cache.v.at[:, slots].set(v_tail.astype(cache.v.dtype))
                cache = KVCache(kc_, vc_, True)
            else:
                kc_ = jax.lax.dynamic_update_slice_in_dim(
                    cache.k, k.astype(cache.k.dtype), 0, axis=1)
                vc_ = jax.lax.dynamic_update_slice_in_dim(
                    cache.v, v.astype(cache.v.dtype), 0, axis=1)
                cache = KVCache(kc_, vc_, False)

    b_, s_, hq, hd = out.shape if out.ndim == 4 else (b, s, cfg.num_heads, cfg.head_dim)
    o = out.reshape(b, -1, cfg.num_heads * cfg.head_dim)
    o = shard(o, None, None, "model")
    return AttnOut(shard(o @ p["wo"], None, None, None), cache)
