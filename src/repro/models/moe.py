"""Mixture-of-Experts FFN (grok-1: 8e top-2; granite: 40e top-8).

TPU-native dispatch: instead of a GPU-style scatter/gather with atomics, we
use the sort-based dispatch that maps onto the MXU + XLA one-hot matmuls:

  router logits -> top-k expert choice per token -> capacity-bounded slot
  assignment via a per-expert cumulative-sum over the (flattened) token axis
  -> one-hot dispatch matmul packs tokens into [E, C, D] expert buffers ->
  grouped expert FFN (einsum over the E axis) -> one-hot combine matmul
  weighted by router probabilities.

Capacity C = ceil(T * top_k / E * capacity_factor); overflowing tokens are
dropped (standard Switch/GShard semantics) — their combine weight is zero and
the residual connection carries them through.

Sharding: expert weights carry an ("expert", "expert_ffn") logical axis pair.
Default ParallelConfig maps expert -> None, expert_ffn -> "model": tensor
parallel *within* every expert, which divides cleanly for both assigned MoE
archs (grok d_ff=32768, granite d_ff=512 -> granite flips to expert-parallel
via the per-arch override; 40 experts don't divide 16 either, so granite uses
expert->None too but d_ff=512 < 16 means expert_ffn drops to replicated —
its expert weights are small). §Perf explores the EP alternative for grok.

Aux loss: GShard/Switch load-balance loss (mean over experts of
fraction_dispatched * mean_router_prob * E), returned to the caller and added
to the task loss with a small coefficient.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import ParamDef, act_fn, shard


def moe_defs(cfg: ModelConfig) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": ParamDef((d, e), ("embed", None), scale=0.1),
        "w_gate": ParamDef((e, d, f), ("expert", "embed", "expert_ffn")),
        "w_up": ParamDef((e, d, f), ("expert", "embed", "expert_ffn")),
        "w_down": ParamDef((e, f, d), ("expert", "expert_ffn", "embed")),
    }


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    per = tokens * cfg.num_experts_per_tok / max(cfg.num_experts, 1)
    cap = int(per * cfg.moe_capacity_factor) + 1
    return min(max(cap, cfg.num_experts_per_tok), tokens)


def _route(cfg: ModelConfig, p: dict, xt: jax.Array, cap: int):
    """Router + capacity-bounded slot assignment (shared by both impls).

    Returns (gate_vals [T,k], gate_idx [T,k], slot [T,k], keep [T,k],
    sel_onehot [T,k,E], probs [T,E])."""
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = xt.shape[0]
    logits = (xt @ p["router"]).astype(jnp.float32)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [T, k]
    # renormalize the chosen gates (mixtral/grok convention)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's queue
    sel_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
    flat_sel = sel_onehot.reshape(t * k, e)
    pos_in_expert = jnp.cumsum(flat_sel, axis=0) - flat_sel   # [T*k, E]
    slot = jnp.sum(pos_in_expert * flat_sel, axis=-1).reshape(t, k)  # [T, k]
    keep = slot < cap
    gate_vals = gate_vals * keep
    return gate_vals, gate_idx, slot.astype(jnp.int32), keep, sel_onehot, \
        probs


def _expert_ffn(cfg: ModelConfig, p: dict, xe: jax.Array) -> jax.Array:
    """Grouped expert FFN on packed buffers [E, C, D] -> [E, C, D].

    Runs on SHARD-LOCAL capacity (see moe_ffn): the token/capacity dims
    are local, only the expert hidden dim shards (TP-within-expert).
    """
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    g = shard(g, None, None, "model")
    u = shard(u, None, None, "model")
    h = act_fn(cfg.act)(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])           # [E, C, D]
    return shard(ye, None, None, None)


def _dispatch_onehot(cfg, p, xt, cap, route):
    """GShard-style one-hot matmul dispatch/combine. O(T*E*C) work —
    MXU-friendly at short T, catastrophic at 32k+ prefill (§Perf)."""
    gate_vals, _, slot, keep, sel_onehot, _ = route
    slot_onehot = jax.nn.one_hot(slot, cap,
                                 dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("tke,tkc->tec", sel_onehot, slot_onehot)  # [T,E,C]
    xe = jnp.einsum("td,tec->ecd", xt.astype(jnp.float32), dispatch)
    xe = shard(xe.astype(xt.dtype), None, None, None)
    ye = _expert_ffn(cfg, p, xe)
    combine = jnp.einsum("tke,tkc,tk->tec", sel_onehot, slot_onehot,
                         gate_vals.astype(jnp.float32))       # [T, E, C]
    return jnp.einsum("tec,ecd->td", combine, ye.astype(jnp.float32))


def _dispatch_scatter(cfg, p, xt, cap, route):
    """Scatter/gather dispatch: pack tokens into [E, C, D] with a
    scatter-add (O(T*k*D)), un-pack with a gather. The §Perf beyond-
    baseline implementation — drops the O(T*E*C) one-hot matmuls that
    dominate long-sequence MoE (granite prefill_32k: 40 experts x 16k
    capacity made dispatch 34x the useful expert FLOPs)."""
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t, d = xt.shape
    gate_vals, gate_idx, slot, keep, _, _ = route
    flat_e = gate_idx.reshape(-1)                     # [T*k]
    # dropped tokens land in a dump slot (index cap) sliced away after
    flat_slot = jnp.where(keep.reshape(-1), slot.reshape(-1), cap)
    xrep = jnp.repeat(xt.astype(jnp.float32), k, axis=0)      # [T*k, D]
    xe = jnp.zeros((e, cap + 1, d), jnp.float32)
    xe = xe.at[flat_e, flat_slot].add(xrep)[:, :cap]
    xe = shard(xe.astype(xt.dtype), None, None, None)
    ye = _expert_ffn(cfg, p, xe)
    yf = ye.astype(jnp.float32)
    safe = jnp.minimum(flat_slot, cap - 1)
    picked = yf[flat_e, safe] * keep.reshape(-1)[:, None]     # [T*k, D]
    return jnp.sum(picked.reshape(t, k, d)
                   * gate_vals.astype(jnp.float32)[..., None], axis=1)


def _moe_local(cfg: ModelConfig, p: dict, xt: jax.Array):
    """Dispatch + expert FFN + combine on a (shard-)local token set."""
    cap = _capacity(xt.shape[0], cfg)
    route = _route(cfg, p, xt, cap)
    if cfg.moe_impl == "scatter":
        out = _dispatch_scatter(cfg, p, xt, cap, route)
    else:
        out = _dispatch_onehot(cfg, p, xt, cap, route)
    # --- load-balance aux loss (Switch) -------------------------------------
    sel_onehot, probs = route[4], route[5]
    frac_tokens = jnp.mean(sel_onehot[:, 0], axis=0)          # top-1 dispatch
    mean_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac_tokens * mean_prob) * cfg.num_experts
    return out, aux


def _auto_batch_axes(tokens: int) -> tuple[str, ...]:
    """Batch-ish mesh axes that are AUTO in the current trace context and
    divide the token count — the axes a serve-path moe can shard-map over.

    In the trainer's manual-data region these axes are Manual (the tokens
    are already local) -> returns (); in plain-jit serving they are Auto
    -> dispatch runs shard-locally per data shard, which is what keeps
    capacity (and the scatter/gather extent) per-shard instead of global.
    """
    from .common import structural_shardmap_enabled
    if not structural_shardmap_enabled():
        return ()
    # older jax lacks abstract-mesh introspection and/or the modern
    # shard_map (which the baxes branch below calls without a mesh):
    # fall back to global-capacity dispatch, which is always correct
    if not hasattr(jax.sharding, "get_abstract_mesh") \
            or not hasattr(jax.sharding, "AxisType") \
            or not hasattr(jax, "shard_map"):
        return ()
    am = jax.sharding.get_abstract_mesh()
    out = []
    size = 1
    for name, ty in zip(am.axis_names, am.axis_types):
        if name != "model" and ty == jax.sharding.AxisType.Auto:
            out.append(name)
            size *= am.shape[name]
    if not out or size <= 1 or tokens % size != 0:
        return ()
    return tuple(out)


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux load-balance loss scalar).

    Token dim semantics: inside the trainer's manual-data shard_map the
    tokens are already shard-local. In auto (serve) context we shard_map
    over the batch axes ourselves so dispatch capacity stays local — a
    global [E, C_global, D] scatter cannot shard its capacity dim and
    would replicate the expert FFN on every chip (measured 13.9x extra
    FLOPs on granite prefill_32k before this, see EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    baxes = _auto_batch_axes(b * s)
    if baxes:
        from repro.jaxcompat import shard_map as shard_map_compat
        out, aux = shard_map_compat(
            lambda pp, xx: _moe_local(cfg, pp, xx),
            axis_names=set(baxes),
            in_specs=(P(), P(baxes)),
            out_specs=(P(baxes), P()),
            check_vma=False,
        )(p, xt)
        aux = aux  # mean over shards is a psum'd scalar already (vma off)
    else:
        out, aux = _moe_local(cfg, p, xt)
    return out.reshape(b, s, d).astype(x.dtype), aux
