"""Decoder-only transformer LM — the chassis for dense / moe / vlm families.

Layers are stacked along a leading "layers" axis and executed with
``lax.scan`` (flat HLO regardless of depth — essential for the 64-layer
grok-1 dry-runs). Per-layer heterogeneity (gemma3's 5 local : 1 global
pattern) is a static per-layer code array scanned alongside the params;
local/global differ only in window + RoPE theta, so a single param set
serves both (lax.cond selects the branch).

The FFN is pluggable: dense MLP (models.common.mlp) or MoE (models.moe).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import moe as moe_lib
from .attention import KVCache, attention_block, attn_defs, cache_spec
from .common import (ParamDef, chunked_ce_loss, embed_defs, embed_lookup,
                     mlp, mlp_defs, rms_norm, shard)


def _stack(defs: Any, n: int) -> Any:
    """Prepend a 'layers' axis to every ParamDef in a layer's def tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _ffn_defs(cfg: ModelConfig) -> dict:
    return moe_lib.moe_defs(cfg) if cfg.family == "moe" else mlp_defs(cfg)


def _ffn_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    if cfg.family == "moe":
        return moe_lib.moe_ffn(cfg, p, x)
    return mlp(cfg, p, x), jnp.float32(0.0)


def layer_defs(cfg: ModelConfig) -> dict:
    return {
        "attn": attn_defs(cfg),
        "ffn": _ffn_defs(cfg),
        "norm_attn": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "norm_ffn": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }


def param_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": embed_defs(cfg),
        "layers": _stack(layer_defs(cfg), cfg.num_layers),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }


def _layer(cfg: ModelConfig, p: dict, x: jax.Array, code: jax.Array, *,
           positions, prefix_len, cache, decode_pos, fill_cache):
    """One transformer layer; ``code``: 0 = global/full, 1 = local/SWA."""
    h = rms_norm(x, p["norm_attn"], cfg.norm_eps)

    def attn_with(window, theta):
        def fn(h_):
            return attention_block(
                cfg, p["attn"], h_, positions=positions, theta=theta,
                window=window, prefix_len=prefix_len, cache=cache,
                decode_pos=decode_pos, fill_cache=fill_cache,
                softcap=cfg.attn_logit_softcap,
                differentiable=not fill_cache)
        return fn

    g_theta = cfg.rope_theta_global or cfg.rope_theta
    if cfg.window_size is None:
        a = attn_with(None, g_theta)(h)
    elif cfg.layer_pattern is None:
        a = attn_with(cfg.window_size, cfg.rope_theta)(h)
    elif isinstance(code, int):   # unrolled serving path: static dispatch
        a = (attn_with(cfg.window_size, cfg.rope_theta) if code == 1
             else attn_with(None, g_theta))(h)
    else:
        a = jax.lax.cond(code == 1,
                         attn_with(cfg.window_size, cfg.rope_theta),
                         attn_with(None, g_theta), h)
    x = x + a.out
    h = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
    f, aux = _ffn_apply(cfg, p["ffn"], h)
    return x + f, a.cache, aux


class Carry(NamedTuple):
    x: jax.Array


def _run_layers(cfg: ModelConfig, params: dict, x: jax.Array, *,
                positions, prefix_len=None, caches=None, decode_pos=None,
                fill_cache=False):
    """Run the stacked layers.

    Train (caches is None): lax.scan over the stacked params — flat HLO.
    Serve (caches given): unrolled Python loop so each layer keeps its own
    cache capacity (ring-buffer for SWA layers, full-length for global) —
    this is what keeps gemma3 long-context caches sub-quadratic.
    """
    codes = jnp.asarray(cfg.pattern_codes(), jnp.int32)

    if caches is None:
        def body(carry, xs):
            lp, code = xs
            y, _, aux = _layer(
                cfg, lp, carry, code, positions=positions,
                prefix_len=prefix_len, cache=None, decode_pos=None,
                fill_cache=False)
            return y, aux

        if cfg.remat:
            body = jax.checkpoint(body)
        if cfg.scan_layers:
            x, auxs = jax.lax.scan(body, x, (params["layers"], codes))
            return x, None, jnp.sum(auxs)
        aux = jnp.float32(0.0)
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, a_i = body(x, (lp, codes[i]))
            aux = aux + a_i
        return x, None, aux

    windows = _layer_windows(cfg)
    static_codes = cfg.pattern_codes()
    new_caches, aux = [], jnp.float32(0.0)
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        # ring semantics degenerate to linear when capacity == seq_len
        cache = KVCache(caches[i]["k"], caches[i]["v"],
                        ring=windows[i] is not None)
        y, nc, a_i = _layer(cfg, lp, x, static_codes[i], positions=positions,
                            prefix_len=prefix_len, cache=cache,
                            decode_pos=decode_pos, fill_cache=fill_cache)
        x, aux = y, aux + a_i
        new_caches.append({"k": nc.k, "v": nc.v})
    return x, tuple(new_caches), aux


def hidden_states(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  prefix_embeds: Optional[jax.Array] = None):
    """Train-mode forward -> (hidden [B,S',D], aux, prefix_len or None)."""
    x = embed_lookup(cfg, params["embed"], tokens)
    prefix_len = None
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = int(prefix_embeds.shape[1])
    positions = jnp.arange(x.shape[1])
    x, _, aux = _run_layers(cfg, params, x, positions=positions,
                            prefix_len=prefix_len)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux, prefix_len


def loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    h, aux, _ = hidden_states(cfg, params, tokens,
                              batch.get("prefix_embeds"))
    if "prefix_embeds" in batch:
        p = batch["prefix_embeds"].shape[1]
        h = h[:, p:]
    ce = chunked_ce_loss(cfg, params["embed"], h[:, :-1], tokens[:, 1:],
                         batch.get("loss_mask"))
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _layer_windows(cfg: ModelConfig) -> list[Optional[int]]:
    codes = cfg.pattern_codes()
    return [cfg.window_size if (c == 1 and cfg.window_size) else None
            for c in codes]


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    """Per-layer KV caches: ring-buffer capacity == window for SWA layers,
    full seq_len for global layers."""
    dtype = dtype or cfg.dtype
    out = []
    for w in _layer_windows(cfg):
        shape, _ = cache_spec(cfg, batch, seq_len, w)
        out.append({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)})
    return tuple(out)


def cache_struct(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    out = []
    for w in _layer_windows(cfg):
        shape, _ = cache_spec(cfg, batch, seq_len, w)
        out.append({"k": jax.ShapeDtypeStruct(shape, dtype),
                    "v": jax.ShapeDtypeStruct(shape, dtype)})
    return tuple(out)


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache):
    """Fill the cache from a prompt; returns (cache, last-token logits)."""
    tokens = batch["tokens"]
    x = embed_lookup(cfg, params["embed"], tokens)
    prefix_len = None
    if "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], 1)
        prefix_len = int(batch["prefix_embeds"].shape[1])
    positions = jnp.arange(x.shape[1])
    x, cache, _ = _run_layers(cfg, params, x, positions=positions,
                              prefix_len=prefix_len, caches=cache,
                              fill_cache=True)
    h = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    from .common import lm_logits
    return cache, lm_logits(cfg, params["embed"], h)


def decode_step(cfg: ModelConfig, params: dict, cache, token: jax.Array,
                pos: jax.Array):
    """One decode step. token: [B,1] i32; pos: scalar i32 absolute position."""
    x = embed_lookup(cfg, params["embed"], token)
    positions = pos[None] if pos.ndim == 0 else pos
    x, cache, _ = _run_layers(cfg, params, x, positions=positions,
                              caches=cache, decode_pos=pos)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    from .common import lm_logits
    return lm_logits(cfg, params["embed"], h), cache
