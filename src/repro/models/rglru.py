"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU recurrent
blocks interleaved 2:1 with local (sliding-window) MQA attention.

RG-LRU recurrence (per channel, c = 8):

    r_t = sigmoid(W_a x_t)          i_t = sigmoid(W_i x_t)
    log a_t = -c * r_t * softplus(Lambda)           (a_t in (0,1))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Train/prefill evaluates the linear recurrence with ``lax.associative_scan``
over time (combine: (a2,b2)∘(a1,b1) = (a1·a2, a2·b1 + b2)) — the TPU-native
replacement for the paper's fused GPU scan kernel. Decode is the exact
one-step recurrence. A causal depthwise conv1d (width 4) precedes the LRU.

Layer layout: pattern (R, R, L) cycled. Training scans over *superblocks* of
three layers (stacked params, flat HLO); a remainder of ``num_layers % 3``
layers is unrolled. Serving unrolls everything (heterogeneous caches).

Recurrent-layer cache: {"h": [B, lru], "conv": [B, w-1, lru]}; attention
cache: ring-buffer KV of capacity == window.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import KVCache, attention_block, attn_defs, cache_spec
from .common import (ParamDef, chunked_ce_loss, embed_defs, embed_lookup,
                     lm_logits, mlp, mlp_defs, rms_norm, shard)

C_RGLRU = 8.0


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------

def lru_defs(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "w_x": ParamDef((d, w), ("embed", "lru")),
        "w_y": ParamDef((d, w), ("embed", "lru")),
        "conv_w": ParamDef((cfg.conv1d_width, w), (None, "lru"), scale=0.3),
        "conv_b": ParamDef((w,), ("lru",), init="zeros"),
        # COLUMN-parallel gate projections (output dim on the model axis):
        # row-parallel ("lru", None) contracts over the sharded dim and
        # forces a 1 GiB f32 all-reduce per gate per layer (52 of the 77
        # big all-reduces in the prefill_32k HLO — EXPERIMENTS.md §Perf);
        # column-parallel needs one shared bf16 all-gather of u instead
        # (4x less wire) and keeps every LRU elementwise op model-sharded.
        "w_a": ParamDef((w, w), (None, "lru"), scale=0.3),
        "w_i": ParamDef((w, w), (None, "lru"), scale=0.3),
        "lam": ParamDef((w,), ("lru",), init="ones"),
        "w_out": ParamDef((w, d), ("lru", "embed")),
    }


def _causal_conv(p: dict, u: jax.Array, tail: Optional[jax.Array]):
    """Depthwise causal conv1d. u: [B,S,W]; tail: [B,cw-1,W] history or None.
    Returns (out [B,S,W], new tail)."""
    cw = p["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    full = jnp.concatenate([tail, u], axis=1)
    out = sum(full[:, i : i + u.shape[1]] * p["conv_w"][i]
              for i in range(cw)) + p["conv_b"]
    return out.astype(u.dtype), full[:, -(cw - 1):]


def _lru_gates(p: dict, x_conv: jax.Array):
    # gate matmuls in the input dtype (bf16 wire/compute), nonlinearities
    # in f32 — the f32 upcast stays BELOW the gather/partial-sum boundary
    r = jax.nn.sigmoid((x_conv @ p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x_conv @ p["w_i"]).astype(jnp.float32))
    log_a = -C_RGLRU * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) \
        * (i * x_conv.astype(jnp.float32))
    return a, gated


def lru_scan(p: dict, x_conv: jax.Array, h0: jax.Array):
    """Associative scan over time. x_conv: [B,S,W]; h0: [B,W] f32."""
    a, b = _lru_gates(p, x_conv)                      # [B,S,W] each
    # fold h0 into the first step: b_0 += a_0 * h0
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def lru_step(p: dict, x_conv: jax.Array, h0: jax.Array):
    """One decode step. x_conv: [B,1,W]."""
    a, b = _lru_gates(p, x_conv)
    h = a[:, 0] * h0 + b[:, 0]
    return h[:, None], h


def recurrent_block(cfg: ModelConfig, p: dict, x: jax.Array,
                    state: Optional[dict], *, decode: bool):
    """Griffin recurrent temporal-mixing block."""
    y = jax.nn.gelu(shard(x @ p["w_y"], None, None, "model"))
    u = shard(x @ p["w_x"], None, None, "model")
    tail = state["conv"] if state is not None else None
    h0 = (state["h"] if state is not None
          else jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32))
    u, new_tail = _causal_conv(p, u, tail)
    if decode:
        h, h_last = lru_step(p, u, h0)
    else:
        h, h_last = lru_scan(p, u, h0)
    out = (h.astype(x.dtype) * y) @ p["w_out"]
    new_state = {"h": h_last, "conv": new_tail}
    return shard(out, None, None, None), new_state


# ---------------------------------------------------------------------------
# hybrid model
# ---------------------------------------------------------------------------

def _block_defs(cfg: ModelConfig, kind: str) -> dict:
    inner = lru_defs(cfg) if kind == "R" else attn_defs(cfg)
    return {
        "mix": inner,
        "norm_mix": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "norm_ffn": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "ffn": mlp_defs(cfg),
    }


def _pattern(cfg: ModelConfig) -> tuple[str, ...]:
    pat = cfg.layer_pattern or ("R", "R", "L")
    return tuple(pat[i % len(pat)] for i in range(cfg.num_layers))


def param_defs(cfg: ModelConfig) -> dict:
    from .transformer import _stack
    pat = _pattern(cfg)
    n_super = cfg.num_layers // 3 if cfg.num_layers >= 3 else 0
    defs: dict[str, Any] = {"embed": embed_defs(cfg)}
    if n_super:
        defs["superblocks"] = _stack(
            {"b0": _block_defs(cfg, pat[0]),
             "b1": _block_defs(cfg, pat[1]),
             "b2": _block_defs(cfg, pat[2])}, n_super)
    for i in range(n_super * 3, cfg.num_layers):
        defs[f"tail_{i}"] = _block_defs(cfg, pat[i])
    defs["final_norm"] = ParamDef((cfg.d_model,), (None,), init="zeros")
    return defs


def _block(cfg: ModelConfig, p: dict, x: jax.Array, kind: str, *,
           positions, cache, decode_pos, fill_cache):
    h = rms_norm(x, p["norm_mix"], cfg.norm_eps)
    if kind == "R":
        a, new_cache = recurrent_block(cfg, p["mix"], h, cache,
                                       decode=decode_pos is not None)
    else:
        kv = (KVCache(cache["k"], cache["v"], ring=True)
              if cache is not None else None)
        out = attention_block(cfg, p["mix"], h, positions=positions,
                              theta=cfg.rope_theta, window=cfg.window_size,
                              cache=kv, decode_pos=decode_pos,
                              fill_cache=fill_cache,
                              differentiable=not fill_cache)
        a = out.out
        new_cache = ({"k": out.cache.k, "v": out.cache.v}
                     if out.cache is not None else None)
    x = x + a
    h = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
    return x + mlp(cfg, p["ffn"], h), new_cache


def _run(cfg: ModelConfig, params: dict, x: jax.Array, *, positions,
         caches=None, decode_pos=None, fill_cache=False):
    pat = _pattern(cfg)
    n_super = cfg.num_layers // 3 if cfg.num_layers >= 3 else 0

    if caches is None and n_super and cfg.scan_layers:
        def body(carry, lp):
            y = carry
            for j, key in enumerate(("b0", "b1", "b2")):
                y, _ = _block(cfg, lp[key], y, pat[j], positions=positions,
                              cache=None, decode_pos=None, fill_cache=False)
            return y, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["superblocks"])
        for i in range(n_super * 3, cfg.num_layers):
            x, _ = _block(cfg, params[f"tail_{i}"], x, pat[i],
                          positions=positions, cache=None, decode_pos=None,
                          fill_cache=False)
        return x, None

    # unrolled (serving, or tiny smoke configs)
    new_caches = []
    for i in range(cfg.num_layers):
        if i < n_super * 3:
            sb, j = divmod(i, 3)
            lp = jax.tree.map(lambda a: a[sb],
                              params["superblocks"][("b0", "b1", "b2")[j]])
        else:
            lp = params[f"tail_{i}"]
        cache = caches[i] if caches is not None else None
        x, nc = _block(cfg, lp, x, pat[i], positions=positions, cache=cache,
                       decode_pos=decode_pos, fill_cache=fill_cache)
        new_caches.append(nc)
    return x, (tuple(new_caches) if caches is not None else None)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    w = cfg.lru_width or cfg.d_model
    out = []
    for kind in _pattern(cfg):
        if kind == "R":
            out.append({"h": jnp.zeros((batch, w), jnp.float32),
                        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w),
                                          dtype)})
        else:
            shape, _ = cache_spec(cfg, batch, seq_len, cfg.window_size)
            out.append({"k": jnp.zeros(shape, dtype),
                        "v": jnp.zeros(shape, dtype)})
    return tuple(out)


def cache_struct(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    w = cfg.lru_width or cfg.d_model
    out = []
    for kind in _pattern(cfg):
        if kind == "R":
            out.append({"h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
                        "conv": jax.ShapeDtypeStruct(
                            (batch, cfg.conv1d_width - 1, w), dtype)})
        else:
            shape, _ = cache_spec(cfg, batch, seq_len, cfg.window_size)
            out.append({"k": jax.ShapeDtypeStruct(shape, dtype),
                        "v": jax.ShapeDtypeStruct(shape, dtype)})
    return tuple(out)


def loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    x = embed_lookup(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])
    x, _ = _run(cfg, params, x, positions=positions)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return chunked_ce_loss(cfg, params["embed"], h[:, :-1], tokens[:, 1:],
                           batch.get("loss_mask"))


def prefill(cfg: ModelConfig, params: dict, batch: dict, caches):
    tokens = batch["tokens"]
    x = embed_lookup(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])
    x, caches = _run(cfg, params, x, positions=positions, caches=caches,
                     fill_cache=True)
    h = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return caches, lm_logits(cfg, params["embed"], h)


def decode_step(cfg: ModelConfig, params: dict, caches, token: jax.Array,
                pos: jax.Array):
    x = embed_lookup(cfg, params["embed"], token)
    positions = pos[None] if pos.ndim == 0 else pos
    x, caches = _run(cfg, params, x, positions=positions, caches=caches,
                     decode_pos=pos)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params["embed"], h), caches
