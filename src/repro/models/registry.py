"""Unified model interface over the six families.

Every family exposes the same five entry points through ``get_model(cfg)``:

    param_defs()                      ParamDef tree
    loss(params, batch)               scalar train loss
    cache_struct(batch, seq)          ShapeDtypeStruct cache tree (None = no decode)
    init_cache(batch, seq)            concrete zero cache
    prefill(params, batch, cache)     (cache, last-token logits)
    decode_step(params, cache, token, pos)  (logits, cache)

plus ``train_inputs`` / ``decode_inputs`` describing the batch as
ShapeDtypeStructs (the dry-run's input_specs building blocks) and
``make_train_batch`` producing concrete synthetic data for smoke tests.

Families: dense / moe / vlm ride the transformer chassis (vlm adds stub
patch embeddings as a bidirectional prefix); rwkv6, hybrid (recurrentgemma),
encdec (whisper), lstm have their own modules.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import encdec, lstm, rglru, rwkv6, transformer
from .common import abstract_params as _abstract, init_params as _init


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    param_defs: Callable[[], Any]
    loss: Callable[[Any, dict], jax.Array]
    cache_struct: Optional[Callable[[int, int], Any]]
    init_cache: Optional[Callable[[int, int], Any]]
    prefill: Optional[Callable[[Any, dict, Any], tuple]]
    decode_step: Optional[Callable[[Any, Any, jax.Array, jax.Array], tuple]]
    # which serve shapes are in-family (DESIGN.md shape-coverage carve-outs)
    supports_decode: bool = True
    supports_long: bool = False

    def init_params(self, seed: int = 0):
        return _init(self.param_defs(), seed, self.cfg.dtype)

    def abstract_params(self, mesh=None, pc=None):
        return _abstract(self.param_defs(), self.cfg.dtype, mesh, pc)

    # ---- batch descriptions -------------------------------------------------
    def train_inputs(self, batch: int, seq: int) -> dict:
        cfg = self.cfg
        d: dict[str, jax.ShapeDtypeStruct] = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        if cfg.family == "vlm":
            d["prefix_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.num_prefix_tokens, cfg.d_model), cfg.dtype)
        if cfg.family == "encdec":
            d["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.encoder_frames, cfg.d_model), cfg.dtype)
        return d

    def decode_inputs(self, batch: int) -> dict:
        return {"token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def make_train_batch(self, batch: int, seq: int, seed: int = 0) -> dict:
        cfg = self.cfg
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        out: dict[str, jax.Array] = {
            "tokens": jax.random.randint(k1, (batch, seq), 0,
                                         cfg.vocab_size, jnp.int32)}
        if cfg.family == "vlm":
            out["prefix_embeds"] = 0.02 * jax.random.normal(
                k2, (batch, cfg.num_prefix_tokens, cfg.d_model), jnp.float32
            ).astype(cfg.dtype)
        if cfg.family == "encdec":
            out["frames"] = 0.02 * jax.random.normal(
                k3, (batch, cfg.encoder_frames, cfg.d_model), jnp.float32
            ).astype(cfg.dtype)
        return out


def _transformer_model(cfg: ModelConfig, *, supports_long: bool) -> Model:
    # vlm: the bidirectional patch-embedding prefix occupies the first
    # num_prefix_tokens cache slots; decode positions are text-relative, so
    # both the RoPE position and the cache slot shift by the prefix length.
    off = cfg.num_prefix_tokens if cfg.family == "vlm" else 0
    return Model(
        cfg=cfg,
        param_defs=lambda: transformer.param_defs(cfg),
        loss=lambda p, b: transformer.loss(cfg, p, b),
        cache_struct=lambda b, s: transformer.cache_struct(cfg, b, s + off),
        init_cache=lambda b, s: transformer.init_cache(cfg, b, s + off),
        prefill=lambda p, b, c: transformer.prefill(cfg, p, b, c),
        decode_step=lambda p, c, t, pos: transformer.decode_step(
            cfg, p, c, t, pos + off),
        supports_decode=True,
        supports_long=supports_long,
    )


def get_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        # long-context decode is in-family only when every layer is windowed
        # or the pattern keeps global layers O(S·d) per token *with* a
        # sub-quadratic total cache: SWA-only archs qualify; archs with any
        # full-attention layer qualify only via the gemma3 local:global
        # pattern (ring caches bound all local layers; the few global layers
        # hold the long cache, O(S) per token decode).
        codes = cfg.pattern_codes()
        all_windowed = all(c == 1 for c in codes) and cfg.window_size
        mostly_windowed = (cfg.window_size is not None
                           and sum(c == 1 for c in codes) >= len(codes) * 0.8)
        return _transformer_model(
            cfg, supports_long=bool(all_windowed or mostly_windowed)
            and fam != "vlm")
    if fam == "rwkv6":
        return Model(
            cfg=cfg,
            param_defs=lambda: rwkv6.param_defs(cfg),
            loss=lambda p, b: rwkv6.loss(cfg, p, b),
            cache_struct=lambda b, s: rwkv6.state_struct(cfg, b),
            init_cache=lambda b, s: rwkv6.init_state(cfg, b),
            prefill=lambda p, b, c: rwkv6.prefill(cfg, p, b, c),
            decode_step=lambda p, c, t, pos: rwkv6.decode_step(
                cfg, p, c, t, pos),
            supports_long=True,
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            param_defs=lambda: rglru.param_defs(cfg),
            loss=lambda p, b: rglru.loss(cfg, p, b),
            cache_struct=lambda b, s: rglru.cache_struct(cfg, b, s),
            init_cache=lambda b, s: rglru.init_cache(cfg, b, s),
            prefill=lambda p, b, c: rglru.prefill(cfg, p, b, c),
            decode_step=lambda p, c, t, pos: rglru.decode_step(
                cfg, p, c, t, pos),
            supports_long=True,
        )
    if fam == "encdec":
        return Model(
            cfg=cfg,
            param_defs=lambda: encdec.param_defs(cfg),
            loss=lambda p, b: encdec.loss(cfg, p, b),
            cache_struct=lambda b, s: encdec.cache_struct(cfg, b, s),
            init_cache=lambda b, s: encdec.init_cache(cfg, b, s),
            prefill=lambda p, b, c: encdec.prefill(cfg, p, b, c),
            decode_step=lambda p, c, t, pos: encdec.decode_step(
                cfg, p, c, t, pos),
            supports_long=False,
        )
    if fam == "lstm":
        return Model(
            cfg=cfg,
            param_defs=lambda: lstm.param_defs(cfg),
            loss=lambda p, b: lstm.loss(cfg, p, b),
            cache_struct=lambda b, s: jax.eval_shape(
                lambda: lstm.init_cache(cfg, b, s)),
            init_cache=lambda b, s: lstm.init_cache(cfg, b, s),
            prefill=lambda p, b, c: lstm.prefill(cfg, p, b, c),
            decode_step=lambda p, c, t, pos: lstm.decode_step(
                cfg, p, c, t, pos),
            supports_long=True,
        )
    raise ValueError(f"unknown family: {fam}")
