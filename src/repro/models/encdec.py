"""Whisper-large-v3 backbone (arXiv:2212.04356): encoder-decoder transformer.

The mel-spectrogram + conv2 frontend is the sanctioned STUB: ``input_specs``
provides precomputed frame embeddings [B, frames, D] (1500 frames = 30 s at
the paper's 2x conv stride). Everything downstream — bidirectional encoder,
causal decoder with cross-attention, KV caches — is fully implemented.

Adaptations (noted in DESIGN.md): sinusoidal positions for both stacks
(whisper's decoder uses a learned table capped at 448 positions; the assigned
``decode_32k`` shape needs arbitrary-length decode, so we use the length-
agnostic sinusoid — the backbone math is otherwise unchanged). MHA (kv == q
heads, per the model card), non-gated GELU MLP.

Cross-attention KV is computed once from the encoder output at prefill and
carried in the cache (no recompute per decode step).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .attention import KVCache, attn_defs, cache_spec, flash_attention, \
    decode_attention, cache_insert, attention_block
from .common import (ParamDef, chunked_ce_loss, embed_defs, embed_lookup,
                     layer_norm, lm_logits, shard)


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mlp_defs(cfg: ModelConfig) -> dict:
    return {"w1": ParamDef((cfg.d_model, cfg.d_ff), ("embed", "ffn")),
            "b1": ParamDef((cfg.d_ff,), ("ffn",), init="zeros"),
            "w2": ParamDef((cfg.d_ff, cfg.d_model), ("ffn", "embed")),
            "b2": ParamDef((cfg.d_model,), (None,), init="zeros")}


def _mlp(p: dict, x: jax.Array) -> jax.Array:
    h = shard(x @ p["w1"] + p["b1"], None, None, "model")
    return shard(jax.nn.gelu(h) @ p["w2"] + p["b2"], None, None, None)


def _enc_layer_defs(cfg: ModelConfig) -> dict:
    return {"attn": attn_defs(cfg), "mlp": _mlp_defs(cfg),
            "ln1": ParamDef((cfg.d_model,), (None,), init="ones"),
            "ln1_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
            "ln2": ParamDef((cfg.d_model,), (None,), init="ones"),
            "ln2_b": ParamDef((cfg.d_model,), (None,), init="zeros")}


def _dec_layer_defs(cfg: ModelConfig) -> dict:
    d = _enc_layer_defs(cfg)
    d["xattn"] = attn_defs(cfg)
    d["ln_x"] = ParamDef((cfg.d_model,), (None,), init="ones")
    d["ln_x_b"] = ParamDef((cfg.d_model,), (None,), init="zeros")
    return d


def param_defs(cfg: ModelConfig) -> dict:
    from .transformer import _stack
    return {
        "embed": embed_defs(cfg),
        "enc_layers": _stack(_enc_layer_defs(cfg), cfg.encoder_layers),
        "dec_layers": _stack(_dec_layer_defs(cfg), cfg.num_layers),
        "enc_norm": ParamDef((cfg.d_model,), (None,), init="ones"),
        "enc_norm_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
        "dec_norm": ParamDef((cfg.d_model,), (None,), init="ones"),
        "dec_norm_b": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }


def _self_attn(cfg, p, x, *, causal, cache=None, decode_pos=None,
               fill_cache=False, differentiable=True):
    b, s, _ = x.shape
    hd, hq = cfg.head_dim, cfg.num_heads
    q = shard(x @ p["wq"], None, None, "model").reshape(b, s, hq, hd)
    k = shard(x @ p["wk"], None, None, None).reshape(b, s, cfg.num_kv_heads, hd)
    v = shard(x @ p["wv"], None, None, None).reshape(b, s, cfg.num_kv_heads, hd)
    if cache is not None and decode_pos is not None:
        cache = cache_insert(cache, k, v, decode_pos)
        out = decode_attention(q, cache, decode_pos)
    else:
        out = flash_attention(q, k, v, causal=causal,
                              q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk,
                              differentiable=differentiable)
        if fill_cache and cache is not None:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), 0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), 0, axis=1)
            cache = KVCache(kc, vc, False)
    o = out.reshape(b, -1, hq * hd)
    return shard(shard(o, None, None, "model") @ p["wo"],
                 None, None, None), cache


def _cross_attn(cfg, p, x, enc_kv, differentiable=True):
    """x: [B,S,D]; enc_kv: (k, v) [B,F,Hkv,hd] precomputed."""
    b, s, _ = x.shape
    hd, hq = cfg.head_dim, cfg.num_heads
    q = shard(x @ p["wq"], None, None, "model").reshape(b, s, hq, hd)
    out = flash_attention(q, enc_kv[0], enc_kv[1], causal=False,
                          q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk,
                          differentiable=differentiable)
    o = out.reshape(b, s, hq * hd)
    return shard(shard(o, None, None, "model") @ p["wo"],
                 None, None, None)


def cross_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array):
    b, f, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(b, f, cfg.num_kv_heads, cfg.head_dim)
    v = (enc_out @ p["wv"]).reshape(b, f, cfg.num_kv_heads, cfg.head_dim)
    return k, v


# ---------------------------------------------------------------------------
# encoder / decoder stacks
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: dict, frames: jax.Array,
           differentiable: bool = True) -> jax.Array:
    """frames: [B, F, D] stub embeddings -> encoder output [B, F, D]."""
    pos = _sinusoid(jnp.arange(frames.shape[1]), cfg.d_model)
    x = frames + pos.astype(frames.dtype)

    def body(carry, lp):
        y = carry
        h = layer_norm(y, lp["ln1"], lp["ln1_b"], cfg.norm_eps)
        a, _ = _self_attn(cfg, lp["attn"], h, causal=False,
                          differentiable=differentiable)
        y = y + a
        h = layer_norm(y, lp["ln2"], lp["ln2_b"], cfg.norm_eps)
        return y + _mlp(lp["mlp"], h), None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.encoder_layers):
            lp = jax.tree.map(lambda a: a[i], params["enc_layers"])
            x, _ = body(x, lp)
    return layer_norm(x, params["enc_norm"], params["enc_norm_b"],
                      cfg.norm_eps)


def _dec_layer(cfg, lp, x, enc_kv, *, cache=None, decode_pos=None,
               fill_cache=False):
    diff = not fill_cache
    h = layer_norm(x, lp["ln1"], lp["ln1_b"], cfg.norm_eps)
    kv = (KVCache(cache["k"], cache["v"], False)
          if cache is not None else None)
    a, kv = _self_attn(cfg, lp["attn"], h, causal=True, cache=kv,
                       decode_pos=decode_pos, fill_cache=fill_cache,
                       differentiable=diff)
    x = x + a
    h = layer_norm(x, lp["ln_x"], lp["ln_x_b"], cfg.norm_eps)
    x = x + _cross_attn(cfg, lp["xattn"], h, enc_kv, differentiable=diff)
    h = layer_norm(x, lp["ln2"], lp["ln2_b"], cfg.norm_eps)
    x = x + _mlp(lp["mlp"], h)
    new_cache = {"k": kv.k, "v": kv.v} if kv is not None else None
    return x, new_cache


def decode_stack(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 enc_out: Optional[jax.Array], *, caches=None,
                 decode_pos=None, fill_cache=False, pos_offset=0):
    x = embed_lookup(cfg, params["embed"], tokens)
    positions = (jnp.arange(tokens.shape[1]) + pos_offset
                 if decode_pos is None else decode_pos[None])
    x = x + _sinusoid(positions, cfg.d_model).astype(x.dtype)

    if caches is None and cfg.scan_layers:
        # training path: cross-kv recomputed per layer inside the scan
        def body(carry, lp):
            y = carry
            ekv = cross_kv(cfg, lp["xattn"], enc_out)
            y, _ = _dec_layer(cfg, lp, y, ekv)
            return y, None
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        new_caches = None
    else:
        new_caches = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["dec_layers"])
            cache = caches[i] if caches is not None else None
            if enc_out is not None:          # prefill: (re)compute cross-KV
                ekv = cross_kv(cfg, lp["xattn"], enc_out)
            else:                            # decode: reuse cached cross-KV
                ekv = (cache["xk"], cache["xv"])
            x, nc = _dec_layer(cfg, lp, x, ekv, cache=cache,
                               decode_pos=decode_pos, fill_cache=fill_cache)
            if nc is not None:
                nc["xk"], nc["xv"] = ekv
            new_caches.append(nc)
        new_caches = tuple(new_caches) if caches is not None else None
    x = layer_norm(x, params["dec_norm"], params["dec_norm_b"], cfg.norm_eps)
    return x, new_caches


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    h, _ = decode_stack(cfg, params, tokens, enc_out)
    return chunked_ce_loss(cfg, params["embed"], h[:, :-1], tokens[:, 1:],
                           batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape, _ = cache_spec(cfg, batch, seq_len, None)
    f = cfg.encoder_frames
    xshape = (batch, f, cfg.num_kv_heads, cfg.head_dim)
    return tuple(
        {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
         "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype)}
        for _ in range(cfg.num_layers))


def cache_struct(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape, _ = cache_spec(cfg, batch, seq_len, None)
    xshape = (batch, cfg.encoder_frames, cfg.num_kv_heads, cfg.head_dim)
    f = lambda sh: jax.ShapeDtypeStruct(sh, dtype)
    return tuple(
        {"k": f(shape), "v": f(shape), "xk": f(xshape), "xv": f(xshape)}
        for _ in range(cfg.num_layers))


def prefill(cfg: ModelConfig, params: dict, batch: dict, caches):
    enc_out = encode(cfg, params, batch["frames"], differentiable=False)
    h, caches = decode_stack(cfg, params, batch["tokens"], enc_out,
                             caches=caches, fill_cache=True)
    return caches, lm_logits(cfg, params["embed"], h[:, -1:])


def decode_step(cfg: ModelConfig, params: dict, caches, token: jax.Array,
                pos: jax.Array):
    h, caches = decode_stack(cfg, params, token, None, caches=caches,
                             decode_pos=pos)
    return lm_logits(cfg, params["embed"], h), caches
