"""The paper's own evaluation model: 2-layer LSTM LM, 1500 hidden units
(Press & Wolf 2016 setup on PTB/Wiki2, RedSync §6.2).

Untied encoder/decoder embeddings (the paper: "we do not tie the weights"),
vanilla SGD + gradient clipping. This model is the convergence test bed for
Table 1 / Table 2 / Fig 6 — it has the paper's signature property: enormous
softmax + embedding layers vs tiny recurrent compute, i.e. the high
communication-to-computation ratio RedSync targets.

The recurrence is a ``lax.scan`` over time (gates batched into one [D, 4H]
matmul). Decode carries (h, c) per layer.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import ParamDef, chunked_ce_loss, shard


def param_defs(cfg: ModelConfig) -> dict:
    d, h, v = cfg.d_model, cfg.d_ff, cfg.vocab_size  # d_ff doubles as hidden
    defs: dict = {
        "embed": {"table": ParamDef((v, d), ("vocab", "embed"),
                                    init="embed", scale=0.05)},
        "lm_head": ParamDef((h, v), (None, "vocab"), scale=0.5),
        "lm_bias": ParamDef((v,), ("vocab",), init="zeros"),
    }
    for i in range(cfg.num_layers):
        in_dim = d if i == 0 else h
        defs[f"lstm_{i}"] = {
            "wx": ParamDef((in_dim, 4 * h), ("embed", None), scale=0.5),
            "wh": ParamDef((h, 4 * h), (None, None), scale=0.5),
            "b": ParamDef((4 * h,), (None,), init="zeros"),
        }
    return defs


def _cell(p: dict, x_t: jax.Array, h_prev: jax.Array, c_prev: jax.Array):
    z = x_t @ p["wx"] + h_prev @ p["wh"] + p["b"]
    i, f, g, o = jnp.split(z.astype(jnp.float32), 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h.astype(x_t.dtype), c


def _run_layer(p: dict, x: jax.Array, h0, c0):
    """x: [B,S,in] -> [B,S,H]; scan over time."""
    def body(carry, x_t):
        h_prev, c_prev = carry
        h, c = _cell(p, x_t, h_prev, c_prev)
        return (h, c), h

    (h_last, c_last), hs = jax.lax.scan(
        body, (h0, c0), x.swapaxes(0, 1))
    return hs.swapaxes(0, 1), h_last, c_last


def _states0(cfg: ModelConfig, batch: int):
    h = cfg.d_ff
    return [(jnp.zeros((batch, h), cfg.dtype), jnp.zeros((batch, h),
                                                         jnp.float32))
            for _ in range(cfg.num_layers)]


def _logits(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    return shard(h @ params["lm_head"] + params["lm_bias"],
                 None, None, "model")


def loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    states = _states0(cfg, tokens.shape[0])
    for i in range(cfg.num_layers):
        x, _, _ = _run_layer(params[f"lstm_{i}"], x, *states[i])
    # untied head: chunked CE against the lm_head projection
    b, s, h = x.shape
    chunk = min(cfg.loss_chunk, s - 1)
    hs, ls = x[:, :-1], tokens[:, 1:]
    n = -(-(s - 1) // chunk)
    pad = n * chunk - (s - 1)
    if pad:
        hs = jnp.pad(hs, ((0, 0), (0, pad), (0, 0)))
        ls = jnp.pad(ls, ((0, 0), (0, pad)))
    mask = (jnp.arange(n * chunk) < (s - 1)).astype(jnp.float32)
    mask = jnp.broadcast_to(mask, (b, n * chunk))

    hs = hs.reshape(b, n, chunk, h).swapaxes(0, 1)
    ls = ls.reshape(b, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        h_c, l_c, m_c = xs
        logits = _logits(cfg, params, h_c).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return (carry[0] + jnp.sum((lse - gold) * m_c),
                carry[1] + jnp.sum(m_c)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)),
        (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    return tuple((jnp.zeros((batch, cfg.d_ff), dtype or cfg.dtype),
                  jnp.zeros((batch, cfg.d_ff), jnp.float32))
                 for _ in range(cfg.num_layers))


def prefill(cfg: ModelConfig, params: dict, batch: dict, states):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    new_states = []
    for i in range(cfg.num_layers):
        x, h, c = _run_layer(params[f"lstm_{i}"], x, *states[i])
        new_states.append((h, c))
    return tuple(new_states), _logits(cfg, params, x[:, -1:])


def decode_step(cfg: ModelConfig, params: dict, states, token: jax.Array,
                pos: jax.Array):
    x = jnp.take(params["embed"]["table"], token, axis=0)
    new_states = []
    for i in range(cfg.num_layers):
        h, c = _cell(params[f"lstm_{i}"], x[:, 0], *states[i])
        x = h[:, None]
        new_states.append((h, c))
    return _logits(cfg, params, x), tuple(new_states)
