"""Deterministic synthetic data pipeline.

Two generators:

* ``SyntheticLM`` — zipf-distributed token stream (marginals match natural
  text); used by throughput/dry-run paths where content doesn't matter.
* ``bigram_batches`` — tokens drawn from a *learnable* random bigram chain.
  A model trained on it has a known achievable loss (the chain's conditional
  entropy), so convergence benchmarks (Tab 1/2, Fig 6 analogues) can compare
  RGC vs dense SGD optimization quality on equal, reproducible footing.

Everything is seeded and stateless-resumable: batch ``i`` is a pure function
of (seed, i), so a restored checkpoint at step i continues the exact stream
(matches the checkpoint substrate's contract).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch_at(self, i: int) -> dict:
        rng = np.random.default_rng((self.seed, i))
        # zipf over a truncated support, remapped through a seed-stable perm
        ranks = rng.zipf(self.zipf_a, size=(self.batch, self.seq_len))
        ranks = np.clip(ranks, 1, self.vocab_size) - 1
        perm = np.random.default_rng(self.seed).permutation(self.vocab_size)
        return {"tokens": perm[ranks].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


def bigram_transition(vocab_size: int, seed: int = 0,
                      concentration: float = 0.3) -> np.ndarray:
    """Row-stochastic transition matrix with entropy well below uniform."""
    rng = np.random.default_rng(seed)
    logits = rng.gumbel(size=(vocab_size, vocab_size)) / concentration
    p = np.exp(logits - logits.max(axis=1, keepdims=True))
    return p / p.sum(axis=1, keepdims=True)


def bigram_entropy(trans: np.ndarray) -> float:
    """Stationary conditional entropy (nats) — the achievable CE floor."""
    # power-iterate the stationary distribution
    pi = np.full(trans.shape[0], 1.0 / trans.shape[0])
    for _ in range(200):
        pi = pi @ trans
    h = -np.sum(pi[:, None] * trans * np.log(np.maximum(trans, 1e-20)))
    return float(h)


def bigram_batches(vocab_size: int, batch: int, seq_len: int,
                   seed: int = 0) -> Iterator[dict]:
    trans = bigram_transition(vocab_size, seed)
    cum = np.cumsum(trans, axis=1)
    i = 0
    while True:
        rng = np.random.default_rng((seed, i))
        toks = np.empty((batch, seq_len), np.int32)
        toks[:, 0] = rng.integers(0, vocab_size, size=batch)
        u = rng.random((batch, seq_len))
        for t in range(1, seq_len):
            rows = cum[toks[:, t - 1]]
            toks[:, t] = (u[:, t, None] < rows).argmax(axis=1)
        yield {"tokens": toks}
        i += 1
