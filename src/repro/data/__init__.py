from .synthetic import SyntheticLM, bigram_batches
