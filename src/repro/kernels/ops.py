"""jit'd wrappers composing the Pallas kernels into RedSync's selectors.

These mirror the pure-jnp selectors in core/selection.py (same Selected
contract) but route the hot loops through the TPU kernels:

    trimmed_topk           = block_stats -> ratio loop(count_gt)
                             -> compact_gt -> exact top-k on the short bucket
    threshold_binary_search = block_stats -> bisect loop(count_gt)
                             -> compact_gt -> first-2k filter

``interpret`` defaults to None = backend auto-detection: compiled kernels
on a TPU backend (the BlockSpec tiling is the lowering target),
interpreter mode everywhere else (CPU tests, debugging). Pass an explicit
bool to override either way. The auto default is what
``compressor_params["backend"] = "pallas"`` threads through the
compressor registry, so a TrainConfig needs no extra knob per platform.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.selection import (Selected, ladder_ratio, mean_of_sum,
                                  search_band, threshold_at)

from .block_stats import abs_sum_max
from .compact import compact_gt
from .residual_update import residual_update as _residual_update_kernel
from .threshold_count import count_gt

DEFAULT_BLOCK = 1024


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> interpret unless running on a real TPU backend."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _to2d(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    n = x.size
    nb = max(1, -(-n // block))
    xp = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, nb * block - n))
    return xp.reshape(nb, block), n


def _cap_for(capacity: int, nb: int, block: int) -> int:
    """Per-block bucket size for gathering ``capacity`` survivors: 4x the
    uniform per-block share, rounded to the 8-sublane granule, clamped to
    the block."""
    per = -(-capacity // nb)
    return min(block, max(8, ((4 * per + 7) // 8) * 8))


def _bucket_cap(k: int, nb: int, block: int) -> int:
    """Bucket size for the k-of-2k selectors (trimmed / exact bsearch)."""
    return _cap_for(2 * k, nb, block)


def stats(x: jax.Array, *, block: int = DEFAULT_BLOCK,
          interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """(mean(|x|), max(|x|)) via the fused reduction kernel."""
    interpret = resolve_interpret(interpret)
    x2d, n = _to2d(x, block)
    s, m = abs_sum_max(x2d, interpret=interpret)
    return mean_of_sum(s, n), m


def nnz_gt(x: jax.Array, threshold: jax.Array, *, block: int = DEFAULT_BLOCK,
           interpret: bool | None = None) -> jax.Array:
    x2d, _ = _to2d(x, block)
    interpret = resolve_interpret(interpret)
    return count_gt(x2d, threshold, interpret=interpret)


def _gather_topk_from_buckets(vals, idx, k: int, total: int,
                              order_by_magnitude: bool):
    """Pick k entries from the [nb, cap] buckets: by |value| (trimmed top-k)
    or simply the first-k valid slots (binary-search filter)."""
    fv, fi = vals.reshape(-1), idx.reshape(-1)
    valid = fi < total
    if order_by_magnitude:
        score = jnp.where(valid, jnp.abs(fv), -1.0)
    else:
        score = valid.astype(jnp.float32)
    _, pos = jax.lax.top_k(score, k)
    sel_idx = jnp.where(valid[pos], fi[pos], total)
    sel_val = jnp.where(valid[pos], fv[pos], 0.0)
    return sel_idx.astype(jnp.int32), sel_val


def trimmed_topk(x: jax.Array, k: int, *, eps: float = 0.2,
                 block: int = DEFAULT_BLOCK,
                 interpret: bool | None = None) -> Selected:
    """Algorithm 2 on the TPU kernels. capacity == k."""
    interpret = resolve_interpret(interpret)
    x2d, n = _to2d(x, block)
    nb = x2d.shape[0]
    s, mx = abs_sum_max(x2d, interpret=interpret)
    mean = mean_of_sum(s, n)

    def cond(state):
        step, nnz = state
        return jnp.logical_and(nnz < k, ladder_ratio(step, eps) > 0.0)

    def body(state):
        step, _ = state
        step = step + 1
        thr = threshold_at(mean, mx, ladder_ratio(step, eps))
        return step, count_gt(x2d, thr, interpret=interpret)

    step0 = jnp.int32(1)
    nnz0 = count_gt(x2d, threshold_at(mean, mx, ladder_ratio(step0, eps)),
                    interpret=interpret)
    step, _ = jax.lax.while_loop(cond, body, (step0, nnz0))
    thr = threshold_at(mean, mx, ladder_ratio(step, eps))

    cap = _bucket_cap(k, nb, block)
    vals, idx, counts = compact_gt(x2d, thr, cap, n, interpret=interpret)
    si, sv = _gather_topk_from_buckets(vals, idx, k, n,
                                       order_by_magnitude=True)
    # Alg 2's coarse (eps=0.2) threshold steps can leave far more than k
    # survivors; if any block overflowed its bucket, elements above the
    # threshold were dropped and the bucket top-k may be wrong — fall back
    # to the exact selector for this (rare) iteration.
    overflow = jnp.any(counts > cap)

    def from_buckets(_):
        return si, sv

    def exact(_):
        from repro.core.selection import exact_topk
        s = exact_topk(x.reshape(-1).astype(jnp.float32), k)
        return s.indices, s.values

    si, sv = jax.lax.cond(overflow, exact, from_buckets, operand=None)
    return Selected(si, sv, jnp.int32(k))


def threshold_binary_search(x: jax.Array, k: int, *, eps: float = 1e-3,
                            warm: jax.Array | None = None,
                            block: int = DEFAULT_BLOCK,
                            interpret: bool | None = None
                            ) -> tuple[Selected, jax.Array]:
    """Algorithm 3 on the TPU kernels. capacity == 2k; returns threshold.

    ``warm`` seeds the bisection bracket from the previous converged
    threshold (``selection.search_band``); ``None`` is the cold search.
    """
    interpret = resolve_interpret(interpret)
    x2d, n = _to2d(x, block)
    s, mx = abs_sum_max(x2d, interpret=interpret)
    mean = mean_of_sum(s, n)
    thr = search_band(lambda t: count_gt(x2d, t, interpret=interpret),
                      mean, mx, k, eps, warm)
    return _filter_2d(x, x2d, n, thr, 2 * k, block,
                      interpret=interpret), thr


def threshold_filter(x: jax.Array, threshold: jax.Array, capacity: int, *,
                     block: int = DEFAULT_BLOCK,
                     interpret: bool | None = None) -> Selected:
    """First-``capacity`` |x| > threshold filter on the TPU kernels.

    Kernel twin of ``selection.threshold_filter`` (same overflow
    semantics, same count header) — the reuse branch of the bsearch
    compressor on the pallas backend, so threshold *reuse* steps skip the
    search kernels entirely instead of re-searching.
    """
    interpret = resolve_interpret(interpret)
    x2d, n = _to2d(x, block)
    return _filter_2d(x, x2d, n, threshold, capacity, block,
                      interpret=interpret)


def _filter_2d(x: jax.Array, x2d: jax.Array, n: int, thr: jax.Array,
               capacity: int, block: int, *, interpret: bool) -> Selected:
    """count -> compact -> first-``capacity`` gather, with the jnp filter
    as the bucket-overflow fallback."""
    nb = x2d.shape[0]
    nnz = count_gt(x2d, thr, interpret=interpret)
    cap = _cap_for(capacity, nb, block)
    vals, idx, counts = compact_gt(x2d, thr, cap, n, interpret=interpret)
    si, sv = _gather_topk_from_buckets(vals, idx, capacity, n,
                                       order_by_magnitude=False)
    # same overflow guard as trimmed_topk (search may exit on r-l <= eps
    # with nnz >> capacity); fall back to the jnp filter for exactness
    overflow = jnp.any(counts > cap)

    def from_buckets(_):
        return si, sv

    def exact(_):
        from repro.core.selection import threshold_filter as jnp_filter
        s = jnp_filter(x.reshape(-1).astype(jnp.float32), thr,
                       capacity=capacity)
        return s.indices, s.values

    si, sv = jax.lax.cond(overflow, exact, from_buckets, operand=None)
    return Selected(si, sv, jnp.minimum(nnz, capacity), nnz > capacity)


def residual_update(grad: jax.Array, u: jax.Array, v: jax.Array, *,
                    momentum: float, nesterov: bool,
                    block: int = DEFAULT_BLOCK,
                    interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Fused U/V update on arbitrary-shaped leaves."""
    interpret = resolve_interpret(interpret)
    shape, n = grad.shape, grad.size
    g2, _ = _to2d(grad, block)
    u2, _ = _to2d(u, block)
    v2, _ = _to2d(v, block)
    u_new, v_new = _residual_update_kernel(
        g2, u2, v2, momentum=momentum, nesterov=nesterov, interpret=interpret)
    return (u_new.reshape(-1)[:n].reshape(shape),
            v_new.reshape(-1)[:n].reshape(shape))
