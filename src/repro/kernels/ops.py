"""jit'd wrappers composing the Pallas kernels into RedSync's selectors.

These mirror the pure-jnp selectors in core/selection.py (same Selected
contract) but route the hot loops through the TPU kernels:

    trimmed_topk           = block_stats -> ratio loop(count_gt)
                             -> compact_gt -> exact top-k on the short bucket
    threshold_binary_search = block_stats -> bisect loop(count_gt)
                             -> compact_gt -> first-2k filter

``interpret`` defaults to None = backend auto-detection: compiled kernels
on a TPU backend (the BlockSpec tiling is the lowering target),
interpreter mode everywhere else (CPU tests, debugging). Pass an explicit
bool to override either way. The auto default is what
``compressor_params["backend"] = "pallas"`` threads through the
compressor registry, so a TrainConfig needs no extra knob per platform.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.selection import (Selected, bisect_midpoint,
                                  mean_of_sum, threshold_at)

from .block_stats import abs_sum_max
from .compact import compact_gt
from .residual_update import residual_update as _residual_update_kernel
from .threshold_count import count_gt

DEFAULT_BLOCK = 1024


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> interpret unless running on a real TPU backend."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _to2d(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    n = x.size
    nb = max(1, -(-n // block))
    xp = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, nb * block - n))
    return xp.reshape(nb, block), n


def _bucket_cap(k: int, nb: int, block: int) -> int:
    """Per-block bucket size: 4x the uniform share of 2k survivors, rounded
    to the 8-sublane granule, clamped to the block."""
    per = -(-2 * k // nb)
    return min(block, max(8, ((4 * per + 7) // 8) * 8))


def stats(x: jax.Array, *, block: int = DEFAULT_BLOCK,
          interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """(mean(|x|), max(|x|)) via the fused reduction kernel."""
    interpret = resolve_interpret(interpret)
    x2d, n = _to2d(x, block)
    s, m = abs_sum_max(x2d, interpret=interpret)
    return mean_of_sum(s, n), m


def nnz_gt(x: jax.Array, threshold: jax.Array, *, block: int = DEFAULT_BLOCK,
           interpret: bool | None = None) -> jax.Array:
    x2d, _ = _to2d(x, block)
    interpret = resolve_interpret(interpret)
    return count_gt(x2d, threshold, interpret=interpret)


def _gather_topk_from_buckets(vals, idx, k: int, total: int,
                              order_by_magnitude: bool):
    """Pick k entries from the [nb, cap] buckets: by |value| (trimmed top-k)
    or simply the first-k valid slots (binary-search filter)."""
    fv, fi = vals.reshape(-1), idx.reshape(-1)
    valid = fi < total
    if order_by_magnitude:
        score = jnp.where(valid, jnp.abs(fv), -1.0)
    else:
        score = valid.astype(jnp.float32)
    _, pos = jax.lax.top_k(score, k)
    sel_idx = jnp.where(valid[pos], fi[pos], total)
    sel_val = jnp.where(valid[pos], fv[pos], 0.0)
    return sel_idx.astype(jnp.int32), sel_val


def trimmed_topk(x: jax.Array, k: int, *, eps: float = 0.2,
                 block: int = DEFAULT_BLOCK,
                 interpret: bool | None = None) -> Selected:
    """Algorithm 2 on the TPU kernels. capacity == k."""
    interpret = resolve_interpret(interpret)
    x2d, n = _to2d(x, block)
    nb = x2d.shape[0]
    s, mx = abs_sum_max(x2d, interpret=interpret)
    mean = mean_of_sum(s, n)

    def cond(state):
        ratio, nnz = state
        return jnp.logical_and(nnz < k, ratio > 0.0)

    def body(state):
        ratio, _ = state
        ratio = ratio - eps
        thr = threshold_at(mean, mx, ratio)
        return ratio, count_gt(x2d, thr, interpret=interpret)

    r0 = jnp.float32(1.0 - eps)
    nnz0 = count_gt(x2d, threshold_at(mean, mx, r0), interpret=interpret)
    ratio, _ = jax.lax.while_loop(cond, body, (r0, nnz0))
    thr = threshold_at(mean, mx, ratio)

    cap = _bucket_cap(k, nb, block)
    vals, idx, counts = compact_gt(x2d, thr, cap, n, interpret=interpret)
    si, sv = _gather_topk_from_buckets(vals, idx, k, n,
                                       order_by_magnitude=True)
    # Alg 2's coarse (eps=0.2) threshold steps can leave far more than k
    # survivors; if any block overflowed its bucket, elements above the
    # threshold were dropped and the bucket top-k may be wrong — fall back
    # to the exact selector for this (rare) iteration.
    overflow = jnp.any(counts > cap)

    def from_buckets(_):
        return si, sv

    def exact(_):
        from repro.core.selection import exact_topk
        s = exact_topk(x.reshape(-1).astype(jnp.float32), k)
        return s.indices, s.values

    si, sv = jax.lax.cond(overflow, exact, from_buckets, operand=None)
    return Selected(si, sv, jnp.int32(k))


def threshold_binary_search(x: jax.Array, k: int, *, eps: float = 1e-3,
                            block: int = DEFAULT_BLOCK,
                            interpret: bool | None = None
                            ) -> tuple[Selected, jax.Array]:
    """Algorithm 3 on the TPU kernels. capacity == 2k; returns threshold."""
    interpret = resolve_interpret(interpret)
    x2d, n = _to2d(x, block)
    nb = x2d.shape[0]
    s, mx = abs_sum_max(x2d, interpret=interpret)
    mean = mean_of_sum(s, n)

    def cond(state):
        l, r, nnz = state
        done = jnp.logical_and(nnz >= k, nnz <= 2 * k)
        return jnp.logical_and(~done, (r - l) > eps)

    def body(state):
        l, r, _ = state
        ratio = bisect_midpoint(l, r)
        thr = threshold_at(mean, mx, ratio)
        nnz = count_gt(x2d, thr, interpret=interpret)
        r = jnp.where(nnz < k, ratio, r)
        l = jnp.where(nnz > 2 * k, ratio, l)
        return l, r, nnz

    l, r, _ = jax.lax.while_loop(
        cond, body, (jnp.float32(0.0), jnp.float32(1.0), jnp.int32(-1)))
    thr = threshold_at(mean, mx, bisect_midpoint(l, r))

    nnz = count_gt(x2d, thr, interpret=interpret)
    cap = _bucket_cap(k, nb, block)
    vals, idx, counts = compact_gt(x2d, thr, cap, n, interpret=interpret)
    si, sv = _gather_topk_from_buckets(vals, idx, 2 * k, n,
                                       order_by_magnitude=False)
    # same overflow guard as trimmed_topk (search may exit on r-l <= eps
    # with nnz >> 2k); fall back to the jnp filter for exactness
    overflow = jnp.any(counts > cap)

    def from_buckets(_):
        return si, sv

    def exact(_):
        from repro.core.selection import threshold_filter
        s = threshold_filter(x.reshape(-1).astype(jnp.float32), thr,
                             capacity=2 * k)
        return s.indices, s.values

    si, sv = jax.lax.cond(overflow, exact, from_buckets, operand=None)
    return Selected(si, sv, jnp.minimum(nnz, 2 * k)), thr


def residual_update(grad: jax.Array, u: jax.Array, v: jax.Array, *,
                    momentum: float, nesterov: bool,
                    block: int = DEFAULT_BLOCK,
                    interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Fused U/V update on arbitrary-shaped leaves."""
    interpret = resolve_interpret(interpret)
    shape, n = grad.shape, grad.size
    g2, _ = _to2d(grad, block)
    u2, _ = _to2d(u, block)
    v2, _ = _to2d(v, block)
    u_new, v_new = _residual_update_kernel(
        g2, u2, v2, momentum=momentum, nesterov=nesterov, interpret=interpret)
    return (u_new.reshape(-1)[:n].reshape(shape),
            v_new.reshape(-1)[:n].reshape(shape))
