"""Segmented Pallas kernels + selectors for the flat residual arenas.

One arena coalesces many same-dtype leaves (``repro.core.arena``); these
kernels run each pipeline stage ONCE over the whole arena while keeping
selection *segmented* — every slot keeps its own ``k_i``, statistics,
threshold and bucket capacity, so the communicated set is bitwise
identical to running the per-leaf selectors leaf by leaf:

* ``seg_abs_sum_max``   — per-segment (sum|x|, max|x|) in one pass (the
                          per-leaf ``block_stats`` twin);
* ``seg_count_gt``      — per-segment nnz(|x| > t_i) with a PER-SEGMENT
                          threshold vector (one launch per search step
                          for the whole arena instead of per leaf);
* ``seg_compact_gt``    — ``compact.compact_gt`` extended to per-segment
                          thresholds and slot-local indices: block-
                          bucketed compaction of every slot's survivors
                          in one launch;
* ``seg_residual_update_stats`` — the fused hot loop: momentum-corrected
                          residual accumulation (Alg 4 l.11-19) AND the
                          Alg 2/3 block statistics of the updated
                          residual in a single pass over the arena (one
                          HBM round-trip instead of two).

Bitwise parity rests on the arena layout: slots are ``ARENA_BLOCK``-
aligned and zero-padded, so each slot's rows are exactly the 2-D view
the per-leaf kernels build, and the sequential grid accumulates each
segment's blocks in the same ascending order as the per-leaf grid.

The ``*_segments`` selectors orchestrate the kernels into Algorithm 2/3
over all slots at once: threshold search loops are vectorized across
segments with converged segments FROZEN (their state stops updating), so
every segment walks the exact iterate sequence its per-leaf loop would.
``use_pallas=False`` routes through the pure-jnp twins in ``ref.py`` —
the same math the per-leaf jnp selectors in ``core.selection`` run.

``interpret`` follows ``ops.resolve_interpret`` (None = auto-detect).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.selection import (Selected, bisect_midpoint, ladder_ratio,
                                  threshold_at, threshold_filter, warm_ratio)

from . import ref
from .ops import _cap_for, _gather_topk_from_buckets, resolve_interpret

__all__ = [
    "seg_abs_sum_max", "seg_count_gt", "seg_compact_gt",
    "seg_residual_update_stats", "seg_stats", "seg_mean",
    "seg_counts", "SegmentSpec", "multi_select",
    "trimmed_topk_segments", "threshold_bsearch_segments",
]


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _lane(n_seg: int) -> jax.Array:
    return jax.lax.broadcasted_iota(jnp.int32, (1, n_seg), 1)


def _pick(vec_ref, seg: jax.Array, n_seg: int) -> jax.Array:
    """One-hot pick of a (1, n_seg) block's ``seg`` entry (TPU-safe —
    no dynamic VMEM scalar indexing)."""
    return jnp.sum(jnp.where(_lane(n_seg) == seg, vec_ref[...], 0.0))


def _stats_kernel(seg_ref, x_ref, sum_ref, max_ref, *, n_seg: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros(sum_ref.shape, sum_ref.dtype)
        max_ref[...] = jnp.zeros(max_ref.shape, max_ref.dtype)

    ax = jnp.abs(x_ref[...].astype(jnp.float32))
    hit = _lane(n_seg) == seg_ref[0, 0]
    sum_ref[...] += jnp.where(hit, jnp.sum(ax), 0.0)
    max_ref[...] = jnp.maximum(max_ref[...],
                               jnp.where(hit, jnp.max(ax), 0.0))


def _stats_kernel_strided(seg_ref, stride_ref, x_ref, sum_ref, max_ref, *,
                          n_seg: int, block: int):
    """Strided-subsample stats: only columns on the row's stride grid
    contribute (strides divide the block, so the masked columns are the
    slot-local ``[::stride]`` subsample the sampled selector defines)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros(sum_ref.shape, sum_ref.dtype)
        max_ref[...] = jnp.zeros(max_ref.shape, max_ref.dtype)

    ax = jnp.abs(x_ref[...].astype(jnp.float32))
    inc = (jax.lax.broadcasted_iota(jnp.int32, ax.shape, 1)
           % stride_ref[0, 0]) == 0
    axm = jnp.where(inc, ax, 0.0)
    hit = _lane(n_seg) == seg_ref[0, 0]
    sum_ref[...] += jnp.where(hit, jnp.sum(axm), 0.0)
    max_ref[...] = jnp.maximum(max_ref[...],
                               jnp.where(hit, jnp.max(axm), 0.0))


def seg_abs_sum_max(x2d: jax.Array, block_seg: np.ndarray, n_seg: int, *,
                    stride_b: np.ndarray | None = None,
                    interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Per-segment (sum|x|, max|x|) over [nb, block] arena rows.

    ``stride_b`` (per-row ints) restricts the statistics to each row's
    stride grid for the sampled selector; ``None`` keeps the exact-path
    kernel (and its graph) untouched.
    """
    nb, block = x2d.shape
    seg = jnp.asarray(block_seg, jnp.int32).reshape(nb, 1)
    row1 = pl.BlockSpec((1, 1), lambda i: (i, 0))
    acc = pl.BlockSpec((1, n_seg), lambda i: (0, 0))
    if stride_b is None:
        kern = functools.partial(_stats_kernel, n_seg=n_seg)
        ins = (seg, x2d)
        in_specs = [row1, pl.BlockSpec((1, block), lambda i: (i, 0))]
    else:
        kern = functools.partial(_stats_kernel_strided, n_seg=n_seg,
                                 block=block)
        stride = jnp.asarray(np.asarray(stride_b), jnp.int32).reshape(nb, 1)
        ins = (seg, stride, x2d)
        in_specs = [row1, row1, pl.BlockSpec((1, block), lambda i: (i, 0))]
    s, m = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=[acc, acc],
        out_shape=[
            jax.ShapeDtypeStruct((1, n_seg), jnp.float32),
            jax.ShapeDtypeStruct((1, n_seg), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(*ins)
    return s[0], m[0]


def _count_kernel(seg_ref, thr_ref, x_ref, out_ref, *, n_seg: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    seg = seg_ref[0, 0]
    thr = _pick(thr_ref, seg, n_seg)
    c = jnp.sum((jnp.abs(x_ref[...].astype(jnp.float32)) > thr)
                .astype(jnp.int32))
    out_ref[...] += jnp.where(_lane(n_seg) == seg, c, 0)


def _count_kernel_strided(seg_ref, stride_ref, thr_ref, x_ref, out_ref, *,
                          n_seg: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    seg = seg_ref[0, 0]
    thr = _pick(thr_ref, seg, n_seg)
    ax = jnp.abs(x_ref[...].astype(jnp.float32))
    inc = (jax.lax.broadcasted_iota(jnp.int32, ax.shape, 1)
           % stride_ref[0, 0]) == 0
    c = jnp.sum(((ax > thr) & inc).astype(jnp.int32))
    out_ref[...] += jnp.where(_lane(n_seg) == seg, c, 0)


def seg_count_gt(x2d: jax.Array, block_seg: np.ndarray,
                 thresholds: jax.Array, *,
                 stride_b: np.ndarray | None = None,
                 interpret: bool | None = None
                 ) -> jax.Array:
    """Per-segment nnz(|x| > thresholds[seg]) — one launch per search
    step for the whole arena (the per-leaf path launches one per leaf).

    ``stride_b`` counts only each row's stride-grid columns (the sampled
    selector's subsample count — integer, so stride-1 rows are exact)."""
    nb, block = x2d.shape
    n_seg = thresholds.shape[0]
    seg = jnp.asarray(block_seg, jnp.int32).reshape(nb, 1)
    thr2d = thresholds.astype(jnp.float32).reshape(1, n_seg)
    row1 = pl.BlockSpec((1, 1), lambda i: (i, 0))
    vec = pl.BlockSpec((1, n_seg), lambda i: (0, 0))
    rowb = pl.BlockSpec((1, block), lambda i: (i, 0))
    if stride_b is None:
        kern = functools.partial(_count_kernel, n_seg=n_seg)
        ins = (seg, thr2d, x2d)
        in_specs = [row1, vec, rowb]
    else:
        kern = functools.partial(_count_kernel_strided, n_seg=n_seg)
        stride = jnp.asarray(np.asarray(stride_b), jnp.int32).reshape(nb, 1)
        ins = (seg, stride, thr2d, x2d)
        in_specs = [row1, row1, vec, rowb]
    out = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((1, n_seg), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(*ins)
    return out[0]


def _compact_kernel(seg_ref, base_ref, size_ref, thr_ref, x_ref,
                    vals_ref, idx_ref, cnt_ref, *, block: int, cap: int,
                    n_seg: int):
    x = x_ref[...].reshape(block).astype(jnp.float32)
    seg = seg_ref[0, 0]
    size = size_ref[0, 0]
    thr = _pick(thr_ref, seg, n_seg)
    lidx = base_ref[0, 0] + jax.lax.iota(jnp.int32, block)
    mask = (jnp.abs(x) > thr) & (lidx < size)

    cnt_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))

    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    live = mask & (pos < cap)
    onehot = (pos[:, None] == jax.lax.iota(jnp.int32, cap)[None, :]) \
        & live[:, None]
    vals_ref[...] = (x[:, None] * onehot.astype(jnp.float32)) \
        .sum(0).reshape(1, cap)
    idx_packed = jnp.where(onehot, lidx[:, None], 0).sum(0)
    filled = jnp.sum(onehot.astype(jnp.int32), axis=0) > 0
    idx_ref[...] = jnp.where(filled, idx_packed, size).reshape(1, cap)


def seg_compact_gt(x2d: jax.Array, block_seg: np.ndarray,
                   block_base: np.ndarray, block_size: np.ndarray,
                   thresholds: jax.Array, cap_per_block: int, *,
                   interpret: bool | None = None
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``compact_gt`` with per-segment thresholds and SLOT-LOCAL indices.

    Returns (values [nb, cap], indices [nb, cap] i32 — local to the
    owning slot, padding == slot size, counts [nb] pre-clamp). Feeding
    the buckets straight into the per-slot message gather removes the
    separate per-leaf pack pass.
    """
    nb, block = x2d.shape
    n_seg = thresholds.shape[0]
    as_col = lambda a: jnp.asarray(a, jnp.int32).reshape(nb, 1)  # noqa: E731
    kern = functools.partial(_compact_kernel, block=block,
                             cap=cap_per_block, n_seg=n_seg)
    vals, idx, cnt = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cap_per_block), lambda i: (i, 0)),
            pl.BlockSpec((1, cap_per_block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, cap_per_block), jnp.float32),
            jax.ShapeDtypeStruct((nb, cap_per_block), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(as_col(block_seg), as_col(block_base), as_col(block_size),
      thresholds.astype(jnp.float32).reshape(1, n_seg), x2d)
    return vals, idx, cnt[:, 0]


def _resid_kernel(*refs, n_seg: int, momentum: float, nesterov: bool,
                  weight_decay: float, round_dtype, has_p: bool):
    it = iter(refs)
    seg_ref = next(it)
    g_ref = next(it)
    v_ref = next(it)
    u_ref = next(it) if momentum else None
    p_ref = next(it) if has_p else None
    v_out = next(it)
    u_out = next(it) if momentum else None
    sum_ref = next(it)
    max_ref = next(it)

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros(sum_ref.shape, sum_ref.dtype)
        max_ref[...] = jnp.zeros(max_ref.shape, max_ref.dtype)

    g = g_ref[...].astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p_ref[...].astype(jnp.float32)
    v = v_ref[...]
    if momentum:
        u = momentum * u_ref[...] + g
        v_new = v + u
        if nesterov:
            v_new = v_new + g
        u_out[...] = u
    else:
        v_new = v + g
    if round_dtype is not None:
        v_new = v_new.astype(round_dtype).astype(jnp.float32)
    v_out[...] = v_new

    ax = jnp.abs(v_new)
    hit = _lane(n_seg) == seg_ref[0, 0]
    sum_ref[...] += jnp.where(hit, jnp.sum(ax), 0.0)
    max_ref[...] = jnp.maximum(max_ref[...],
                               jnp.where(hit, jnp.max(ax), 0.0))


def seg_residual_update_stats(
    g2d: jax.Array,
    v2d: jax.Array,
    u2d: jax.Array | None,
    p2d: jax.Array | None,
    block_seg: np.ndarray,
    n_seg: int,
    *,
    momentum: float,
    nesterov: bool,
    weight_decay: float = 0.0,
    round_dtype=None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array | None, jax.Array, jax.Array]:
    """Fused Alg 4 accumulation + Alg 2/3 statistics in ONE arena pass.

    Returns (V' [nb, block], U' or None, per-seg sum|V'|, per-seg
    max|V'|). ``round_dtype`` rounds V' through the residual storage
    dtype (bf16 residuals) before statistics, matching the per-leaf
    store-then-reload sequence bitwise. ``u2d`` is required iff
    ``momentum`` is nonzero; ``p2d`` iff ``weight_decay`` is nonzero.
    """
    nb, block = g2d.shape
    if momentum and u2d is None:
        raise ValueError("momentum accumulation needs the velocity arena")
    if weight_decay and p2d is None:
        raise ValueError("weight decay needs the parameter arena")
    seg = jnp.asarray(block_seg, jnp.int32).reshape(nb, 1)
    row = pl.BlockSpec((1, block), lambda i: (i, 0))
    acc = pl.BlockSpec((1, n_seg), lambda i: (0, 0))

    ins = [seg, g2d, v2d]
    in_specs = [pl.BlockSpec((1, 1), lambda i: (i, 0)), row, row]
    if momentum:
        ins.append(u2d)
        in_specs.append(row)
    if weight_decay:
        ins.append(p2d)
        in_specs.append(row)
    out_specs = [row]
    out_shape = [jax.ShapeDtypeStruct((nb, block), jnp.float32)]
    if momentum:
        out_specs.append(row)
        out_shape.append(jax.ShapeDtypeStruct((nb, block), jnp.float32))
    out_specs += [acc, acc]
    out_shape += [jax.ShapeDtypeStruct((1, n_seg), jnp.float32),
                  jax.ShapeDtypeStruct((1, n_seg), jnp.float32)]

    kern = functools.partial(
        _resid_kernel, n_seg=n_seg, momentum=momentum, nesterov=nesterov,
        weight_decay=weight_decay, round_dtype=round_dtype,
        has_p=bool(weight_decay))
    outs = pl.pallas_call(
        kern, grid=(nb,), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=resolve_interpret(interpret),
    )(*ins)
    outs = list(outs)
    v_new = outs.pop(0)
    u_new = outs.pop(0) if momentum else None
    sums, maxs = outs
    return v_new, u_new, sums[0], maxs[0]


# ---------------------------------------------------------------------------
# Segmented selectors (Algorithm 2/3 across all slots at once)
# ---------------------------------------------------------------------------

def seg_mean(sums: jax.Array, geom, stride_seg=None) -> jax.Array:
    """Per-segment mean from per-segment sums — the pinned reciprocal
    multiply of ``selection.mean_of_sum``, vectorized over slots. The
    ONE definition both ``seg_stats`` and the fused accumulate+stats
    path use, so their statistics can never diverge. ``stride_seg``
    divides by each slot's SAMPLED element count instead (the sampled
    selector's subsample mean)."""
    from repro.core.residual import pinned_product
    if stride_seg is None:
        ns = geom.seg_sizes
    else:
        ns = [-(-n // int(s)) for n, s in zip(geom.seg_sizes, stride_seg)]
    recip = jnp.asarray([jnp.float32(1.0 / n) for n in ns])
    return pinned_product(sums, recip)


def seg_stats(x2d: jax.Array, geom, *, use_pallas: bool,
              interpret: bool | None = None, stride_seg=None
              ) -> tuple[jax.Array, jax.Array]:
    """Per-segment (mean|x|, max|x|). The jnp twin reduces each slot's
    own [nblocks, block] rows with the shapes ``selection._stats`` uses,
    so per-leaf statistics are reproduced bitwise on either backend.
    ``stride_seg`` computes subsample statistics for the sampled paths
    (``None`` / all-ones keeps the exact kernels untouched)."""
    strided = stride_seg is not None and any(int(s) > 1 for s in stride_seg)
    if not strided:
        stride_seg = None
    if use_pallas:
        stride_b = None if stride_seg is None else \
            np.asarray(stride_seg, np.int32)[np.asarray(geom.block_seg)]
        sums, maxs = seg_abs_sum_max(x2d, geom.block_seg, geom.n_seg,
                                     stride_b=stride_b, interpret=interpret)
    else:
        sums, maxs = ref.seg_abs_sum_max(x2d, geom.block_seg,
                                         geom.block_size, geom.n_seg,
                                         stride_seg)
    return seg_mean(sums, geom, stride_seg), maxs


def seg_counts(x2d: jax.Array, geom, thresholds: jax.Array, *,
               use_pallas: bool, interpret: bool | None = None,
               stride_b=None) -> jax.Array:
    if use_pallas:
        return seg_count_gt(x2d, geom.block_seg, thresholds,
                            stride_b=stride_b, interpret=interpret)
    return ref.seg_count_gt(x2d, geom.block_seg, thresholds, geom.n_seg,
                            stride_b)


def _seg_buckets(x2d, geom, thresholds, cap, *, use_pallas, interpret):
    if use_pallas:
        return seg_compact_gt(x2d, geom.block_seg, geom.block_base,
                              geom.block_size, thresholds, cap,
                              interpret=interpret)
    return ref.seg_compact_gt(x2d, geom.block_seg, geom.block_base,
                              geom.block_size, thresholds, cap)


def _slot_flat(x2d: jax.Array, geom, s: int) -> jax.Array:
    """Slot ``s`` as the flat f32[size] vector the per-leaf path sees."""
    r0, r1 = geom.seg_rows[s]
    return x2d[r0:r1].reshape(-1)[:geom.seg_sizes[s]]


class SegmentSpec(NamedTuple):
    """One arena's selection request for ``multi_select``.

    ``alg`` picks the search (Alg 2 ratio ladder vs Alg 3 bisection);
    the runtime fields drive §5.2.2 threshold reuse (``refresh`` /
    ``cached``), warm-started bisection (``warm``) and DGC-style sampled
    counting (``strides`` — per-slot subsample strides; all-1 is exact).
    ``capacities`` are per-slot message capacities (defaulting to ``k``
    for trimmed and ``2k`` for bsearch when empty).
    """
    alg: str                              # "trimmed" | "bsearch"
    eps: float
    capacities: tuple[int, ...] = ()
    strides: tuple[int, ...] = ()
    refresh: jax.Array | None = None      # bool[n_seg]
    cached: jax.Array | None = None       # f32[n_seg]
    warm: bool = False


def _norm_caps(spec: SegmentSpec, geom) -> tuple[int, ...]:
    if spec.capacities:
        return tuple(spec.capacities)
    if spec.alg == "trimmed":
        return tuple(geom.seg_ks)
    return tuple(2 * k for k in geom.seg_ks)


def _norm_strides(spec: SegmentSpec, geom) -> tuple[int, ...]:
    if spec.strides and spec.alg == "bsearch":
        return tuple(int(s) for s in spec.strides)
    return (1,) * geom.n_seg


def multi_select(
    parts: list[tuple[jax.Array, Any, SegmentSpec,
                      tuple[jax.Array, jax.Array] | None]],
    *,
    use_pallas: bool,
    interpret: bool | None = None,
) -> list[tuple[list[Selected], jax.Array]]:
    """Algorithm 2 AND 3 across every slot of every arena in ONE dispatch
    per search iteration.

    ``parts`` is ``[(x2d, geometry, SegmentSpec, stats-or-None), ...]``
    — one entry per arena. The arenas are row-stacked into a virtual
    super-arena (``arena.stack_geometries``) and both threshold walks run
    in a single unified ``while_loop``: trimmed segments step their
    pinned ratio ladder, bsearch segments bisect their bracket, and every
    iteration issues ONE ``seg_count_gt`` launch for all segments of all
    arenas. Converged (or reuse / warm-accepted) segments are FROZEN —
    their carried state stops updating — so each segment still walks
    exactly the iterate sequence its per-leaf selector would, and the
    selected sets stay bitwise identical to the per-leaf path. Bucket
    compaction is likewise one ``seg_compact_gt`` launch for everything.

    Returns one ``(selections, thresholds)`` pair per part, in order.
    """
    geoms = [p[1] for p in parts]
    specs = [p[2] for p in parts]
    if len(parts) == 1:
        x_all, geom_all = parts[0][0], geoms[0]
    else:
        from repro.core.arena import stack_geometries
        x_all = jnp.concatenate([p[0] for p in parts], axis=0)
        geom_all = stack_geometries(geoms)

    n = geom_all.n_seg
    k_vec = jnp.asarray(geom_all.seg_ks, jnp.int32)
    two_k = 2 * k_vec

    # --- static per-segment vectors -------------------------------------
    trim_np = np.concatenate([
        np.full(g.n_seg, s.alg == "trimmed") for g, s in zip(geoms, specs)])
    eps_np = np.concatenate([
        np.full(g.n_seg, s.eps, np.float32) for g, s in zip(geoms, specs)])
    warm_np = np.concatenate([
        np.full(g.n_seg, bool(s.warm) and s.alg == "bsearch")
        for g, s in zip(geoms, specs)])
    strides = sum((_norm_strides(s, g) for g, s in zip(geoms, specs)), ())
    caps_sel = sum((_norm_caps(s, g) for g, s in zip(geoms, specs)), ())
    is_trim = jnp.asarray(trim_np)
    eps_vec = jnp.asarray(eps_np)
    warm_vec = jnp.asarray(warm_np)
    any_trim = bool(trim_np.any())
    any_warm = bool(warm_np.any())
    sampled = any(s > 1 for s in strides)
    stride_b = np.asarray(strides, np.int64)[
        np.asarray(geom_all.block_seg)].astype(np.int32) if sampled else None
    stride_vec = jnp.asarray(strides, jnp.int32)

    # --- runtime per-segment vectors ------------------------------------
    refresh = jnp.concatenate([
        jnp.asarray(s.refresh) if s.refresh is not None
        else jnp.ones((g.n_seg,), bool) for g, s in zip(geoms, specs)])
    have_cached = any(s.cached is not None for s in specs)
    cached = jnp.concatenate([
        jnp.asarray(s.cached, jnp.float32) if s.cached is not None
        else jnp.zeros((g.n_seg,), jnp.float32)
        for g, s in zip(geoms, specs)])

    # --- statistics (per-segment — independent of arena grouping) -------
    if all(p[3] is None for p in parts):
        mean, mx = seg_stats(x_all, geom_all, use_pallas=use_pallas,
                             interpret=interpret,
                             stride_seg=strides if sampled else None)
    else:
        means, maxs = [], []
        for (x2d, geom, spec, stats) in parts:
            if stats is None:
                st = _norm_strides(spec, geom)
                stats = seg_stats(
                    x2d, geom, use_pallas=use_pallas, interpret=interpret,
                    stride_seg=st if any(s > 1 for s in st) else None)
            means.append(stats[0])
            maxs.append(stats[1])
        mean, mx = jnp.concatenate(means), jnp.concatenate(maxs)

    def count_est(thr):
        """One launch: per-segment survivor counts; sampled segments
        count their subsample and scale by the stride (integer — exact
        segments are untouched by the scaling)."""
        cnt = seg_counts(x_all, geom_all, thr, use_pallas=use_pallas,
                         interpret=interpret, stride_b=stride_b)
        return cnt * stride_vec if sampled else cnt

    def in_band(nz):
        return (nz >= k_vec) & (nz <= two_k)

    # --- initial probe: trimmed rung 1 + warm cached thresholds ---------
    step0 = jnp.ones((n,), jnp.int32)
    if any_trim or any_warm:
        thr0 = jnp.where(is_trim,
                         threshold_at(mean, mx, ladder_ratio(step0, eps_vec)),
                         cached)
        cnt0 = count_est(thr0)
        accept = warm_vec & refresh & ~is_trim & in_band(cnt0)
        use0 = is_trim | warm_vec
        nnz0 = jnp.where(use0, cnt0, jnp.int32(-1))
        r_prev = warm_ratio(cached, mean, mx)
        seed = warm_vec & ~is_trim
        l0 = jnp.where(seed & (cnt0 > two_k), r_prev,
                       jnp.zeros((n,), jnp.float32))
        r0 = jnp.where(seed & (cnt0 < k_vec), r_prev,
                       jnp.ones((n,), jnp.float32))
    else:
        accept = jnp.zeros((n,), bool)
        nnz0 = jnp.full((n,), -1, jnp.int32)
        l0 = jnp.zeros((n,), jnp.float32)
        r0 = jnp.ones((n,), jnp.float32)

    # --- unified search loop: one count launch per iteration ------------
    def trim_active(step, nnz):
        return is_trim & (nnz < k_vec) & (ladder_ratio(step, eps_vec) > 0.0)

    def bs_active(l, r, nnz):
        return (~is_trim & refresh & ~accept & ~in_band(nnz)
                & ((r - l) > eps_vec))

    def cond(state):
        step, l, r, nnz = state
        return jnp.any(trim_active(step, nnz) | bs_active(l, r, nnz))

    def body(state):
        step, l, r, nnz = state
        ta = trim_active(step, nnz)
        ba = bs_active(l, r, nnz)
        step = jnp.where(ta, step + 1, step)
        ratio_b = bisect_midpoint(l, r)
        ratio = jnp.where(is_trim, ladder_ratio(step, eps_vec), ratio_b)
        cnt = count_est(threshold_at(mean, mx, ratio))
        nnz = jnp.where(ta | ba, cnt, nnz)
        r = jnp.where(ba & (cnt < k_vec), ratio_b, r)
        l = jnp.where(ba & (cnt > two_k), ratio_b, l)
        return step, l, r, nnz

    step, l, r, nnz_loop = jax.lax.while_loop(
        cond, body, (step0, l0, r0, nnz0))

    ratio_fin = jnp.where(is_trim, ladder_ratio(step, eps_vec),
                          bisect_midpoint(l, r))
    thr = threshold_at(mean, mx, ratio_fin)
    if any_warm:
        thr = jnp.where(accept, cached, thr)
    if have_cached:
        thr = jnp.where(is_trim | refresh, thr, cached)

    # --- one full count + one compaction for every arena ----------------
    nnz_full = seg_counts(x_all, geom_all, thr, use_pallas=use_pallas,
                          interpret=interpret)
    caps = [_cap_for(2 * k if t else max(2 * k, c), r1 - r0, geom_all.block)
            for t, k, c, (r0, r1) in zip(
                trim_np, geom_all.seg_ks, caps_sel, geom_all.seg_rows)]
    cap_max = max(caps)
    vals, idx, cnts = _seg_buckets(x_all, geom_all, thr, cap_max,
                                   use_pallas=use_pallas,
                                   interpret=interpret)

    # --- per-slot gathers (plain jnp on the short buckets) --------------
    results: list[tuple[list[Selected], jax.Array]] = []
    seg0 = 0
    for (x2d, geom, spec, _stats) in parts:
        out: list[Selected] = []
        for sl, ((prow0, prow1), k, size) in enumerate(
                zip(geom.seg_rows, geom.seg_ks, geom.seg_sizes)):
            s = seg0 + sl
            row0, row1 = geom_all.seg_rows[s]
            cap = caps[s]
            cap_sel = caps_sel[s]
            if spec.alg == "trimmed":
                si, sv = _gather_topk_from_buckets(
                    vals[row0:row1, :cap], idx[row0:row1, :cap], k, size,
                    order_by_magnitude=True)
                overflow = jnp.any(cnts[row0:row1] > cap)
                if use_pallas:
                    # mirror ops.trimmed_topk: exact fallback on overflow
                    fallback = overflow

                    def exact(_, sl=sl, k=k, x2d=x2d, geom=geom):
                        from repro.core.selection import exact_topk
                        e = exact_topk(_slot_flat(x2d, geom, sl), k)
                        return e.indices, e.values
                else:
                    # mirror selection.trimmed_topk (no buckets at all):
                    # the full top-k pads with real zero-score indices
                    # when nnz < k
                    fallback = overflow | (nnz_loop[s] < k)

                    def exact(_, sl=sl, k=k, t=thr[s], x2d=x2d, geom=geom):
                        from repro.core.selection import _pad_topk
                        flat = _slot_flat(x2d, geom, sl)
                        score = jnp.where(jnp.abs(flat) > t,
                                          jnp.abs(flat), 0.0)
                        e = _pad_topk(flat, score, k)
                        return e.indices, e.values

                si, sv = jax.lax.cond(fallback, exact,
                                      lambda _, si=si, sv=sv: (si, sv),
                                      operand=None)
                out.append(Selected(si, sv, jnp.int32(k)))
            else:
                si, sv = _gather_topk_from_buckets(
                    vals[row0:row1, :cap], idx[row0:row1, :cap], cap_sel,
                    size, order_by_magnitude=False)
                overflow = jnp.any(cnts[row0:row1] > cap)

                def exact(_, sl=sl, c=cap_sel, t=thr[s], x2d=x2d, geom=geom):
                    e = threshold_filter(_slot_flat(x2d, geom, sl), t,
                                         capacity=c)
                    return e.indices, e.values

                si, sv = jax.lax.cond(overflow, exact,
                                      lambda _, si=si, sv=sv: (si, sv),
                                      operand=None)
                out.append(Selected(si, sv,
                                    jnp.minimum(nnz_full[s], cap_sel),
                                    nnz_full[s] > cap_sel))
        results.append((out, thr[seg0:seg0 + geom.n_seg]))
        seg0 += geom.n_seg
    return results


def trimmed_topk_segments(
    x2d: jax.Array,
    geom,
    *,
    eps: float = 0.2,
    use_pallas: bool,
    interpret: bool | None = None,
    stats: tuple[jax.Array, jax.Array] | None = None,
) -> list[Selected]:
    """Algorithm 2 over every slot of one arena (capacity == k_i each).

    Single-arena wrapper over ``multi_select`` (the ratio walk runs
    vectorized with converged segments frozen, so each slot's final
    threshold is bitwise the per-leaf loop's).
    """
    spec = SegmentSpec(alg="trimmed", eps=eps)
    ((sel, _thr),) = multi_select([(x2d, geom, spec, stats)],
                                  use_pallas=use_pallas, interpret=interpret)
    return sel


def threshold_bsearch_segments(
    x2d: jax.Array,
    geom,
    *,
    eps: float = 1e-3,
    use_pallas: bool,
    interpret: bool | None = None,
    stats: tuple[jax.Array, jax.Array] | None = None,
    refresh: jax.Array | None = None,
    cached: jax.Array | None = None,
    warm: bool = False,
    strides: tuple[int, ...] = (),
    capacities: tuple[int, ...] = (),
) -> tuple[list[Selected], jax.Array]:
    """Algorithm 3 over every slot of one arena (capacity == 2 k_i each
    unless ``capacities`` overrides, e.g. the sampled selector's
    tolerance headroom).

    ``refresh``/``cached`` implement §5.2.2 threshold reuse (segments
    with ``refresh[s] == False`` skip the bisect entirely and filter at
    ``cached[s]``); ``warm`` seeds refreshing segments' brackets from
    ``cached``; ``strides`` turns on sampled counting. Single-arena
    wrapper over ``multi_select``. Returns the per-slot selections and
    the per-segment thresholds used (the new ``LeafState.threshold``
    cache).
    """
    spec = SegmentSpec(alg="bsearch", eps=eps, capacities=capacities,
                       strides=strides, refresh=refresh, cached=cached,
                       warm=warm)
    ((sel, thr),) = multi_select([(x2d, geom, spec, stats)],
                                 use_pallas=use_pallas, interpret=interpret)
    return sel, thr
