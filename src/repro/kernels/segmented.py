"""Segmented Pallas kernels + selectors for the flat residual arenas.

One arena coalesces many same-dtype leaves (``repro.core.arena``); these
kernels run each pipeline stage ONCE over the whole arena while keeping
selection *segmented* — every slot keeps its own ``k_i``, statistics,
threshold and bucket capacity, so the communicated set is bitwise
identical to running the per-leaf selectors leaf by leaf:

* ``seg_abs_sum_max``   — per-segment (sum|x|, max|x|) in one pass (the
                          per-leaf ``block_stats`` twin);
* ``seg_count_gt``      — per-segment nnz(|x| > t_i) with a PER-SEGMENT
                          threshold vector (one launch per search step
                          for the whole arena instead of per leaf);
* ``seg_compact_gt``    — ``compact.compact_gt`` extended to per-segment
                          thresholds and slot-local indices: block-
                          bucketed compaction of every slot's survivors
                          in one launch;
* ``seg_residual_update_stats`` — the fused hot loop: momentum-corrected
                          residual accumulation (Alg 4 l.11-19) AND the
                          Alg 2/3 block statistics of the updated
                          residual in a single pass over the arena (one
                          HBM round-trip instead of two).

Bitwise parity rests on the arena layout: slots are ``ARENA_BLOCK``-
aligned and zero-padded, so each slot's rows are exactly the 2-D view
the per-leaf kernels build, and the sequential grid accumulates each
segment's blocks in the same ascending order as the per-leaf grid.

The ``*_segments`` selectors orchestrate the kernels into Algorithm 2/3
over all slots at once: threshold search loops are vectorized across
segments with converged segments FROZEN (their state stops updating), so
every segment walks the exact iterate sequence its per-leaf loop would.
``use_pallas=False`` routes through the pure-jnp twins in ``ref.py`` —
the same math the per-leaf jnp selectors in ``core.selection`` run.

``interpret`` follows ``ops.resolve_interpret`` (None = auto-detect).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.selection import (Selected, bisect_midpoint,
                                  mean_of_sum, threshold_at,
                                  threshold_filter)

from . import ref
from .ops import _bucket_cap, _gather_topk_from_buckets, resolve_interpret

__all__ = [
    "seg_abs_sum_max", "seg_count_gt", "seg_compact_gt",
    "seg_residual_update_stats", "seg_stats", "seg_mean",
    "seg_counts",
    "trimmed_topk_segments", "threshold_bsearch_segments",
]


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _lane(n_seg: int) -> jax.Array:
    return jax.lax.broadcasted_iota(jnp.int32, (1, n_seg), 1)


def _pick(vec_ref, seg: jax.Array, n_seg: int) -> jax.Array:
    """One-hot pick of a (1, n_seg) block's ``seg`` entry (TPU-safe —
    no dynamic VMEM scalar indexing)."""
    return jnp.sum(jnp.where(_lane(n_seg) == seg, vec_ref[...], 0.0))


def _stats_kernel(seg_ref, x_ref, sum_ref, max_ref, *, n_seg: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros(sum_ref.shape, sum_ref.dtype)
        max_ref[...] = jnp.zeros(max_ref.shape, max_ref.dtype)

    ax = jnp.abs(x_ref[...].astype(jnp.float32))
    hit = _lane(n_seg) == seg_ref[0, 0]
    sum_ref[...] += jnp.where(hit, jnp.sum(ax), 0.0)
    max_ref[...] = jnp.maximum(max_ref[...],
                               jnp.where(hit, jnp.max(ax), 0.0))


def seg_abs_sum_max(x2d: jax.Array, block_seg: np.ndarray, n_seg: int, *,
                    interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Per-segment (sum|x|, max|x|) over [nb, block] arena rows."""
    nb, block = x2d.shape
    seg = jnp.asarray(block_seg, jnp.int32).reshape(nb, 1)
    s, m = pl.pallas_call(
        functools.partial(_stats_kernel, n_seg=n_seg),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
            pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n_seg), jnp.float32),
            jax.ShapeDtypeStruct((1, n_seg), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(seg, x2d)
    return s[0], m[0]


def _count_kernel(seg_ref, thr_ref, x_ref, out_ref, *, n_seg: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

    seg = seg_ref[0, 0]
    thr = _pick(thr_ref, seg, n_seg)
    c = jnp.sum((jnp.abs(x_ref[...].astype(jnp.float32)) > thr)
                .astype(jnp.int32))
    out_ref[...] += jnp.where(_lane(n_seg) == seg, c, 0)


def seg_count_gt(x2d: jax.Array, block_seg: np.ndarray,
                 thresholds: jax.Array, *, interpret: bool | None = None
                 ) -> jax.Array:
    """Per-segment nnz(|x| > thresholds[seg]) — one launch per search
    step for the whole arena (the per-leaf path launches one per leaf)."""
    nb, block = x2d.shape
    n_seg = thresholds.shape[0]
    seg = jnp.asarray(block_seg, jnp.int32).reshape(nb, 1)
    out = pl.pallas_call(
        functools.partial(_count_kernel, n_seg=n_seg),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_seg), jnp.int32),
        interpret=resolve_interpret(interpret),
    )(seg, thresholds.astype(jnp.float32).reshape(1, n_seg), x2d)
    return out[0]


def _compact_kernel(seg_ref, base_ref, size_ref, thr_ref, x_ref,
                    vals_ref, idx_ref, cnt_ref, *, block: int, cap: int,
                    n_seg: int):
    x = x_ref[...].reshape(block).astype(jnp.float32)
    seg = seg_ref[0, 0]
    size = size_ref[0, 0]
    thr = _pick(thr_ref, seg, n_seg)
    lidx = base_ref[0, 0] + jax.lax.iota(jnp.int32, block)
    mask = (jnp.abs(x) > thr) & (lidx < size)

    cnt_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))

    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    live = mask & (pos < cap)
    onehot = (pos[:, None] == jax.lax.iota(jnp.int32, cap)[None, :]) \
        & live[:, None]
    vals_ref[...] = (x[:, None] * onehot.astype(jnp.float32)) \
        .sum(0).reshape(1, cap)
    idx_packed = jnp.where(onehot, lidx[:, None], 0).sum(0)
    filled = jnp.sum(onehot.astype(jnp.int32), axis=0) > 0
    idx_ref[...] = jnp.where(filled, idx_packed, size).reshape(1, cap)


def seg_compact_gt(x2d: jax.Array, block_seg: np.ndarray,
                   block_base: np.ndarray, block_size: np.ndarray,
                   thresholds: jax.Array, cap_per_block: int, *,
                   interpret: bool | None = None
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``compact_gt`` with per-segment thresholds and SLOT-LOCAL indices.

    Returns (values [nb, cap], indices [nb, cap] i32 — local to the
    owning slot, padding == slot size, counts [nb] pre-clamp). Feeding
    the buckets straight into the per-slot message gather removes the
    separate per-leaf pack pass.
    """
    nb, block = x2d.shape
    n_seg = thresholds.shape[0]
    as_col = lambda a: jnp.asarray(a, jnp.int32).reshape(nb, 1)  # noqa: E731
    kern = functools.partial(_compact_kernel, block=block,
                             cap=cap_per_block, n_seg=n_seg)
    vals, idx, cnt = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cap_per_block), lambda i: (i, 0)),
            pl.BlockSpec((1, cap_per_block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, cap_per_block), jnp.float32),
            jax.ShapeDtypeStruct((nb, cap_per_block), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(as_col(block_seg), as_col(block_base), as_col(block_size),
      thresholds.astype(jnp.float32).reshape(1, n_seg), x2d)
    return vals, idx, cnt[:, 0]


def _resid_kernel(*refs, n_seg: int, momentum: float, nesterov: bool,
                  weight_decay: float, round_dtype, has_p: bool):
    it = iter(refs)
    seg_ref = next(it)
    g_ref = next(it)
    v_ref = next(it)
    u_ref = next(it) if momentum else None
    p_ref = next(it) if has_p else None
    v_out = next(it)
    u_out = next(it) if momentum else None
    sum_ref = next(it)
    max_ref = next(it)

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = jnp.zeros(sum_ref.shape, sum_ref.dtype)
        max_ref[...] = jnp.zeros(max_ref.shape, max_ref.dtype)

    g = g_ref[...].astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p_ref[...].astype(jnp.float32)
    v = v_ref[...]
    if momentum:
        u = momentum * u_ref[...] + g
        v_new = v + u
        if nesterov:
            v_new = v_new + g
        u_out[...] = u
    else:
        v_new = v + g
    if round_dtype is not None:
        v_new = v_new.astype(round_dtype).astype(jnp.float32)
    v_out[...] = v_new

    ax = jnp.abs(v_new)
    hit = _lane(n_seg) == seg_ref[0, 0]
    sum_ref[...] += jnp.where(hit, jnp.sum(ax), 0.0)
    max_ref[...] = jnp.maximum(max_ref[...],
                               jnp.where(hit, jnp.max(ax), 0.0))


def seg_residual_update_stats(
    g2d: jax.Array,
    v2d: jax.Array,
    u2d: jax.Array | None,
    p2d: jax.Array | None,
    block_seg: np.ndarray,
    n_seg: int,
    *,
    momentum: float,
    nesterov: bool,
    weight_decay: float = 0.0,
    round_dtype=None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array | None, jax.Array, jax.Array]:
    """Fused Alg 4 accumulation + Alg 2/3 statistics in ONE arena pass.

    Returns (V' [nb, block], U' or None, per-seg sum|V'|, per-seg
    max|V'|). ``round_dtype`` rounds V' through the residual storage
    dtype (bf16 residuals) before statistics, matching the per-leaf
    store-then-reload sequence bitwise. ``u2d`` is required iff
    ``momentum`` is nonzero; ``p2d`` iff ``weight_decay`` is nonzero.
    """
    nb, block = g2d.shape
    if momentum and u2d is None:
        raise ValueError("momentum accumulation needs the velocity arena")
    if weight_decay and p2d is None:
        raise ValueError("weight decay needs the parameter arena")
    seg = jnp.asarray(block_seg, jnp.int32).reshape(nb, 1)
    row = pl.BlockSpec((1, block), lambda i: (i, 0))
    acc = pl.BlockSpec((1, n_seg), lambda i: (0, 0))

    ins = [seg, g2d, v2d]
    in_specs = [pl.BlockSpec((1, 1), lambda i: (i, 0)), row, row]
    if momentum:
        ins.append(u2d)
        in_specs.append(row)
    if weight_decay:
        ins.append(p2d)
        in_specs.append(row)
    out_specs = [row]
    out_shape = [jax.ShapeDtypeStruct((nb, block), jnp.float32)]
    if momentum:
        out_specs.append(row)
        out_shape.append(jax.ShapeDtypeStruct((nb, block), jnp.float32))
    out_specs += [acc, acc]
    out_shape += [jax.ShapeDtypeStruct((1, n_seg), jnp.float32),
                  jax.ShapeDtypeStruct((1, n_seg), jnp.float32)]

    kern = functools.partial(
        _resid_kernel, n_seg=n_seg, momentum=momentum, nesterov=nesterov,
        weight_decay=weight_decay, round_dtype=round_dtype,
        has_p=bool(weight_decay))
    outs = pl.pallas_call(
        kern, grid=(nb,), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=resolve_interpret(interpret),
    )(*ins)
    outs = list(outs)
    v_new = outs.pop(0)
    u_new = outs.pop(0) if momentum else None
    sums, maxs = outs
    return v_new, u_new, sums[0], maxs[0]


# ---------------------------------------------------------------------------
# Segmented selectors (Algorithm 2/3 across all slots at once)
# ---------------------------------------------------------------------------

def seg_mean(sums: jax.Array, geom) -> jax.Array:
    """Per-segment mean from per-segment sums — the pinned reciprocal
    multiply of ``selection.mean_of_sum``, vectorized over slots. The
    ONE definition both ``seg_stats`` and the fused accumulate+stats
    path use, so their statistics can never diverge."""
    from repro.core.residual import pinned_product
    recip = jnp.asarray([jnp.float32(1.0 / n) for n in geom.seg_sizes])
    return pinned_product(sums, recip)


def seg_stats(x2d: jax.Array, geom, *, use_pallas: bool,
              interpret: bool | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """Per-segment (mean|x|, max|x|). The jnp twin reduces each slot's
    own [nblocks, block] rows with the shapes ``selection._stats`` uses,
    so per-leaf statistics are reproduced bitwise on either backend."""
    if use_pallas:
        sums, maxs = seg_abs_sum_max(x2d, geom.block_seg, geom.n_seg,
                                     interpret=interpret)
    else:
        sums, maxs = ref.seg_abs_sum_max(x2d, geom.block_seg,
                                         geom.block_size, geom.n_seg)
    return seg_mean(sums, geom), maxs


def seg_counts(x2d: jax.Array, geom, thresholds: jax.Array, *,
               use_pallas: bool, interpret: bool | None = None) -> jax.Array:
    if use_pallas:
        return seg_count_gt(x2d, geom.block_seg, thresholds,
                            interpret=interpret)
    return ref.seg_count_gt(x2d, geom.block_seg, thresholds, geom.n_seg)


def _seg_buckets(x2d, geom, thresholds, cap, *, use_pallas, interpret):
    if use_pallas:
        return seg_compact_gt(x2d, geom.block_seg, geom.block_base,
                              geom.block_size, thresholds, cap,
                              interpret=interpret)
    return ref.seg_compact_gt(x2d, geom.block_seg, geom.block_base,
                              geom.block_size, thresholds, cap)


def _caps(geom, block: int) -> tuple[list[int], int]:
    caps = [_bucket_cap(k, r1 - r0, block)
            for k, (r0, r1) in zip(geom.seg_ks, geom.seg_rows)]
    return caps, max(caps)


def _slot_flat(x2d: jax.Array, geom, s: int) -> jax.Array:
    """Slot ``s`` as the flat f32[size] vector the per-leaf path sees."""
    r0, r1 = geom.seg_rows[s]
    return x2d[r0:r1].reshape(-1)[:geom.seg_sizes[s]]


def trimmed_topk_segments(
    x2d: jax.Array,
    geom,
    *,
    eps: float = 0.2,
    use_pallas: bool,
    interpret: bool | None = None,
    stats: tuple[jax.Array, jax.Array] | None = None,
) -> list[Selected]:
    """Algorithm 2 over every slot of one arena (capacity == k_i each).

    The ratio walk runs vectorized with converged segments frozen, so
    each slot's final threshold is bitwise the per-leaf loop's. Per-slot
    bucket gathers fall back to the exact selector exactly when the
    per-leaf path would (bucket overflow; on the jnp twin also the
    under-k case the full top-k handles by padding with real indices).
    """
    mean, mx = stats if stats is not None else seg_stats(
        x2d, geom, use_pallas=use_pallas, interpret=interpret)
    k_vec = jnp.asarray(geom.seg_ks, jnp.int32)
    count = functools.partial(seg_counts, x2d, geom, use_pallas=use_pallas,
                              interpret=interpret)

    r0 = jnp.full((geom.n_seg,), jnp.float32(1.0 - eps))
    nnz0 = count(threshold_at(mean, mx, r0))

    def cond(state):
        ratio, nnz = state
        return jnp.any((nnz < k_vec) & (ratio > 0.0))

    def body(state):
        ratio, nnz = state
        active = (nnz < k_vec) & (ratio > 0.0)
        ratio = jnp.where(active, ratio - eps, ratio)
        cnt = count(threshold_at(mean, mx, ratio))
        return ratio, jnp.where(active, cnt, nnz)

    ratio, nnz = jax.lax.while_loop(cond, body, (r0, nnz0))
    thr = threshold_at(mean, mx, ratio)

    caps, cap_max = _caps(geom, geom.block)
    vals, idx, cnts = _seg_buckets(x2d, geom, thr, cap_max,
                                   use_pallas=use_pallas,
                                   interpret=interpret)

    out: list[Selected] = []
    for s, ((row0, row1), k, n, cap) in enumerate(
            zip(geom.seg_rows, geom.seg_ks, geom.seg_sizes, caps)):
        si, sv = _gather_topk_from_buckets(
            vals[row0:row1, :cap], idx[row0:row1, :cap], k, n,
            order_by_magnitude=True)
        overflow = jnp.any(cnts[row0:row1] > cap)
        if use_pallas:
            # mirror ops.trimmed_topk: exact fallback on overflow only
            fallback = overflow

            def exact(_, s=s, k=k):
                from repro.core.selection import exact_topk
                e = exact_topk(_slot_flat(x2d, geom, s), k)
                return e.indices, e.values
        else:
            # mirror selection.trimmed_topk (no buckets at all): the full
            # top-k pads with real zero-score indices when nnz < k
            fallback = overflow | (nnz[s] < k)

            def exact(_, s=s, k=k, t=thr[s]):
                from repro.core.selection import _pad_topk
                flat = _slot_flat(x2d, geom, s)
                score = jnp.where(jnp.abs(flat) > t, jnp.abs(flat), 0.0)
                e = _pad_topk(flat, score, k)
                return e.indices, e.values

        si, sv = jax.lax.cond(fallback, exact,
                              lambda _, si=si, sv=sv: (si, sv),
                              operand=None)
        out.append(Selected(si, sv, jnp.int32(k)))
    return out


def threshold_bsearch_segments(
    x2d: jax.Array,
    geom,
    *,
    eps: float = 1e-3,
    use_pallas: bool,
    interpret: bool | None = None,
    stats: tuple[jax.Array, jax.Array] | None = None,
    refresh: jax.Array | None = None,
    cached: jax.Array | None = None,
) -> tuple[list[Selected], jax.Array]:
    """Algorithm 3 over every slot of one arena (capacity == 2 k_i each).

    ``refresh``/``cached`` implement the §5.2.2 sampled variant: segments
    with ``refresh[s] == False`` skip the bisect entirely and filter at
    ``cached[s]``. Returns the per-slot selections and the per-segment
    thresholds used (the new ``LeafState.threshold`` cache).
    """
    mean, mx = stats if stats is not None else seg_stats(
        x2d, geom, use_pallas=use_pallas, interpret=interpret)
    k_vec = jnp.asarray(geom.seg_ks, jnp.int32)
    two_k = 2 * k_vec
    count = functools.partial(seg_counts, x2d, geom, use_pallas=use_pallas,
                              interpret=interpret)
    if refresh is None:
        refresh = jnp.ones((geom.n_seg,), bool)

    def searching(l, r, nnz):
        done = (nnz >= k_vec) & (nnz <= two_k)
        return refresh & ~done & ((r - l) > eps)

    def cond(state):
        l, r, nnz = state
        return jnp.any(searching(l, r, nnz))

    def body(state):
        l, r, nnz = state
        active = searching(l, r, nnz)
        ratio = bisect_midpoint(l, r)
        cnt = count(threshold_at(mean, mx, ratio))
        nnz = jnp.where(active, cnt, nnz)
        r = jnp.where(active & (cnt < k_vec), ratio, r)
        l = jnp.where(active & (cnt > two_k), ratio, l)
        return l, r, nnz

    l, r, _ = jax.lax.while_loop(
        cond, body, (jnp.zeros((geom.n_seg,), jnp.float32),
                     jnp.ones((geom.n_seg,), jnp.float32),
                     jnp.full((geom.n_seg,), -1, jnp.int32)))
    thr = threshold_at(mean, mx, bisect_midpoint(l, r))
    if cached is not None:
        thr = jnp.where(refresh, thr, cached)

    nnz = count(thr)
    caps, cap_max = _caps(geom, geom.block)
    vals, idx, cnts = _seg_buckets(x2d, geom, thr, cap_max,
                                   use_pallas=use_pallas,
                                   interpret=interpret)

    out: list[Selected] = []
    for s, ((row0, row1), k, n, cap) in enumerate(
            zip(geom.seg_rows, geom.seg_ks, geom.seg_sizes, caps)):
        si, sv = _gather_topk_from_buckets(
            vals[row0:row1, :cap], idx[row0:row1, :cap], 2 * k, n,
            order_by_magnitude=False)
        overflow = jnp.any(cnts[row0:row1] > cap)

        def exact(_, s=s, k=k, t=thr[s]):
            e = threshold_filter(_slot_flat(x2d, geom, s), t,
                                 capacity=2 * k)
            return e.indices, e.values

        si, sv = jax.lax.cond(overflow, exact,
                              lambda _, si=si, sv=sv: (si, sv),
                              operand=None)
        out.append(Selected(si, sv, jnp.minimum(nnz[s], 2 * k)))
    return out, thr
