"""Pallas TPU kernel: fused |x| sum+max reduction (Alg 2/3 statistics).

Single pass over the residual in VMEM-sized blocks; the sequential TPU grid
accumulates into a (1,1) output block (constant index_map) — the TPU idiom
replacing a GPU two-level tree reduction. mean = sum / n is formed by the
caller (ops.py) so padding contributes nothing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, sum_ref, max_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sum_ref[0, 0] = 0.0
        max_ref[0, 0] = 0.0

    ax = jnp.abs(x_ref[...].astype(jnp.float32))
    sum_ref[0, 0] += jnp.sum(ax)
    max_ref[0, 0] = jnp.maximum(max_ref[0, 0], jnp.max(ax))


def abs_sum_max(x2d: jax.Array, *, interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """x2d: [nb, block] (pre-padded with zeros). Returns (sum|x|, max|x|)."""
    nb, block = x2d.shape
    s, m = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2d)
    return s[0, 0], m[0, 0]
