"""Pallas TPU kernel: nnz(|x| > t) — the count_nonzero inner loop of Alg 3.

The binary-search selector calls this once per search step; on TPU the count
is a VPU compare + popcount-style sum per VMEM block, accumulated across the
sequential grid into a (1,1) i32 block. The threshold arrives as a (1,1)
operand so the *same compiled kernel* serves every search iteration (the
paper re-launches a CUDA kernel per step; here the while_loop re-invokes the
pallas_call with a new scalar).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(thr_ref, x_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = 0

    mask = jnp.abs(x_ref[...].astype(jnp.float32)) > thr_ref[0, 0]
    out_ref[0, 0] += jnp.sum(mask.astype(jnp.int32))


def count_gt(x2d: jax.Array, threshold: jax.Array, *, interpret: bool = True) -> jax.Array:
    """x2d: [nb, block] zero-padded; threshold scalar (>=0 drops the padding
    automatically since |0| > t is false for t >= 0). Returns i32 count."""
    nb, block = x2d.shape
    thr = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(thr, x2d)
    return out[0, 0]
