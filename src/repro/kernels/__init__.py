"""Pallas TPU kernels for RedSync's compression hot spots.

Validated in interpret mode on CPU; TPU is the lowering target.
"""
from . import ops, ref
