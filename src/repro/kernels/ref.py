"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def abs_sum_max(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(sum(|x|), max(|x|)) — the statistics feeding Alg 2/3 thresholds."""
    ax = jnp.abs(x.astype(jnp.float32))
    return jnp.sum(ax), jnp.max(ax)


def count_gt(x: jax.Array, threshold: jax.Array) -> jax.Array:
    """nnz(|x| > threshold) as i32 — the count_nonzero hot loop of Alg 3."""
    return jnp.sum(jnp.abs(x.astype(jnp.float32)) > threshold).astype(jnp.int32)


def compact_gt(
    x: jax.Array, threshold: jax.Array, block: int, cap_per_block: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Block-bucketed stream compaction oracle.

    Splits flat ``x`` into ``block``-sized blocks; within each block emits the
    first ``cap_per_block`` elements with |x| > threshold (padded with index
    == x.size, value 0) plus the per-block survivor count (pre-clamp).

    Returns (values [nb, cap], indices [nb, cap] i32, counts [nb] i32).
    """
    n = x.size
    nb = -(-n // block)
    xp = jnp.pad(x.astype(jnp.float32).reshape(-1), (0, nb * block - n))
    xb = xp.reshape(nb, block)
    gidx = jnp.arange(nb * block).reshape(nb, block)
    mask = (jnp.abs(xb) > threshold) & (gidx < n)

    def per_block(xrow, mrow, grow):
        (pos,) = jnp.nonzero(mrow, size=cap_per_block, fill_value=block)
        safe = jnp.minimum(pos, block - 1)
        vals = jnp.where(pos < block, xrow[safe], 0.0)
        idxs = jnp.where(pos < block, grow[safe], n)
        return vals, idxs.astype(jnp.int32), jnp.sum(mrow).astype(jnp.int32)

    return jax.vmap(per_block)(xb, mask, gidx)


def residual_update(
    grad: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    momentum: float,
    nesterov: bool,
) -> tuple[jax.Array, jax.Array]:
    """Fused momentum-correction + residual accumulation (Alg 4 l.11–19)."""
    g = grad.astype(jnp.float32)
    u_new = momentum * u + g
    v_new = v + u_new
    if nesterov:
        v_new = v_new + g
    return u_new, v_new


# ---------------------------------------------------------------------------
# Segmented twins (the flat-arena kernels of kernels/segmented.py)
# ---------------------------------------------------------------------------

def _seg_rows(block_seg) -> list[tuple[int, int]]:
    """Contiguous [row0, row1) row range per segment ordinal."""
    bs = np.asarray(block_seg)
    starts = np.searchsorted(bs, np.arange(bs.max() + 1), side="left")
    ends = np.searchsorted(bs, np.arange(bs.max() + 1), side="right")
    return [(int(s), int(e)) for s, e in zip(starts, ends)]


def seg_abs_sum_max(x2d: jax.Array, block_seg, block_size,
                    n_seg: int, stride_seg=None
                    ) -> tuple[jax.Array, jax.Array]:
    """Per-segment (sum|x|, max|x|) over the arena's [nb, block] rows.

    Each segment's sum runs ``selection.pinned_sum`` over the slot's
    TRUE-length flat vector (padding sliced off) — the exact pinned
    summation tree ``selection._stats`` runs for that leaf on its own,
    so the per-segment mean is bitwise the per-leaf mean in any graph
    context. ``block_size`` carries the owning slot's true size per row.

    ``stride_seg`` (per-segment ints) restricts the statistics to the
    slot's ``[::stride]`` subsample — the same vector the sampled
    per-leaf selector slices, so sampled per-leaf and sampled segmented
    statistics stay bitwise too. ``None`` / stride 1 is the exact path.
    """
    from repro.core.selection import pinned_sum
    ax = jnp.abs(x2d.astype(jnp.float32))
    bsize = np.asarray(block_size)
    sums, maxs = [], []
    for s, (r0, r1) in enumerate(_seg_rows(block_seg)):
        seg = ax[r0:r1]
        stride = 1 if stride_seg is None else int(stride_seg[s])
        if stride > 1:
            vec = seg.reshape(-1)[:int(bsize[r0]):stride]
            sums.append(pinned_sum(vec))
            maxs.append(jnp.max(vec))
        else:
            sums.append(pinned_sum(seg.reshape(-1)[:int(bsize[r0])]))
            maxs.append(jnp.max(seg))
    return jnp.stack(sums), jnp.stack(maxs)


def seg_count_gt(x2d: jax.Array, block_seg, thresholds: jax.Array,
                 n_seg: int, stride_b=None) -> jax.Array:
    """Per-segment nnz(|x| > thresholds[seg]) (integer — order-free).

    ``stride_b`` (per-row ints) counts only columns on the row's stride
    grid — the sampled paths' subsample count. Strides divide the block
    and slots are block-aligned, so ``col % stride == 0`` is exactly the
    slot-local ``[::stride]`` grid the per-leaf sampled count scans.
    """
    seg = jnp.asarray(np.asarray(block_seg), jnp.int32)
    thr_b = jnp.asarray(thresholds, jnp.float32)[seg]
    mask = jnp.abs(x2d.astype(jnp.float32)) > thr_b[:, None]
    if stride_b is not None:
        col = jnp.arange(x2d.shape[1], dtype=jnp.int32)[None, :]
        sb = jnp.asarray(np.asarray(stride_b), jnp.int32)[:, None]
        mask = mask & (col % sb == 0)
    cnt_b = jnp.sum(mask, axis=1).astype(jnp.int32)
    return jax.ops.segment_sum(cnt_b, seg, num_segments=n_seg)


def seg_compact_gt(x2d: jax.Array, block_seg, block_base, block_size,
                   thresholds: jax.Array, cap_per_block: int
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Block-bucketed compaction with per-segment thresholds.

    Twin of ``segmented.seg_compact_gt``: per arena row, the first
    ``cap_per_block`` elements with |x| > thr of the owning segment are
    packed to the front; indices are slot-LOCAL with padding == the
    slot's size; counts are pre-clamp survivor counts.
    """
    nb, block = x2d.shape
    x = x2d.astype(jnp.float32)
    seg = jnp.asarray(np.asarray(block_seg), jnp.int32)
    base = jnp.asarray(np.asarray(block_base), jnp.int32)
    size = jnp.asarray(np.asarray(block_size), jnp.int32)
    thr_b = jnp.asarray(thresholds, jnp.float32)[seg]

    lidx = base[:, None] + jnp.arange(block, dtype=jnp.int32)[None, :]
    mask = (jnp.abs(x) > thr_b[:, None]) & (lidx < size[:, None])
    cnts = jnp.sum(mask, axis=1).astype(jnp.int32)

    cap = cap_per_block
    pos = jnp.cumsum(mask, axis=1) - 1
    live = mask & (pos < cap)
    row = jnp.arange(nb)[:, None]
    # scatter survivors into [nb, cap] buckets (+1 dump slot for the rest)
    tgt = jnp.where(live, row * cap + pos, nb * cap).reshape(-1)
    vals = jnp.zeros(nb * cap + 1, jnp.float32) \
        .at[tgt].set(x.reshape(-1))[:nb * cap].reshape(nb, cap)
    sentinel = jnp.broadcast_to(size[:, None], (nb, cap)).reshape(-1)
    idx = jnp.concatenate([sentinel, jnp.zeros(1, jnp.int32)]) \
        .at[tgt].set(lidx.reshape(-1))[:nb * cap].reshape(nb, cap)
    return vals, idx.astype(jnp.int32), cnts


def seg_residual_update_stats(
    g2d: jax.Array,
    v2d: jax.Array,
    u2d: jax.Array | None,
    p2d: jax.Array | None,
    block_seg,
    n_seg: int,
    *,
    momentum: float,
    nesterov: bool,
    weight_decay: float = 0.0,
    round_dtype=None,
) -> tuple[jax.Array, jax.Array | None, jax.Array, jax.Array]:
    """Twin of the fused arena accumulate+stats pass (Alg 4 + Alg 2/3)."""
    g = g2d.astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p2d.astype(jnp.float32)
    if momentum:
        u_new, v_new = residual_update(g, u2d, v2d, momentum=momentum,
                                       nesterov=nesterov)
    else:
        u_new, v_new = None, v2d + g
    if round_dtype is not None:
        v_new = v_new.astype(round_dtype).astype(jnp.float32)
    sums, maxs = _plain_seg_abs_sum_max(v_new, block_seg, n_seg)
    return v_new, u_new, sums, maxs


def _plain_seg_abs_sum_max(x2d, block_seg, n_seg):
    """Sequential-blockwise per-segment stats (the fused-kernel oracle:
    the Pallas grid accumulates block sums in ascending row order)."""
    ax = jnp.abs(x2d.astype(jnp.float32))
    sums, maxs = [], []
    for r0, r1 in _seg_rows(block_seg):
        seg = ax[r0:r1]
        sums.append(jnp.sum(jnp.sum(seg, axis=1)))
        maxs.append(jnp.max(seg))
    return jnp.stack(sums), jnp.stack(maxs)
