"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def abs_sum_max(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(sum(|x|), max(|x|)) — the statistics feeding Alg 2/3 thresholds."""
    ax = jnp.abs(x.astype(jnp.float32))
    return jnp.sum(ax), jnp.max(ax)


def count_gt(x: jax.Array, threshold: jax.Array) -> jax.Array:
    """nnz(|x| > threshold) as i32 — the count_nonzero hot loop of Alg 3."""
    return jnp.sum(jnp.abs(x.astype(jnp.float32)) > threshold).astype(jnp.int32)


def compact_gt(
    x: jax.Array, threshold: jax.Array, block: int, cap_per_block: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Block-bucketed stream compaction oracle.

    Splits flat ``x`` into ``block``-sized blocks; within each block emits the
    first ``cap_per_block`` elements with |x| > threshold (padded with index
    == x.size, value 0) plus the per-block survivor count (pre-clamp).

    Returns (values [nb, cap], indices [nb, cap] i32, counts [nb] i32).
    """
    n = x.size
    nb = -(-n // block)
    xp = jnp.pad(x.astype(jnp.float32).reshape(-1), (0, nb * block - n))
    xb = xp.reshape(nb, block)
    gidx = jnp.arange(nb * block).reshape(nb, block)
    mask = (jnp.abs(xb) > threshold) & (gidx < n)

    def per_block(xrow, mrow, grow):
        (pos,) = jnp.nonzero(mrow, size=cap_per_block, fill_value=block)
        safe = jnp.minimum(pos, block - 1)
        vals = jnp.where(pos < block, xrow[safe], 0.0)
        idxs = jnp.where(pos < block, grow[safe], n)
        return vals, idxs.astype(jnp.int32), jnp.sum(mrow).astype(jnp.int32)

    return jax.vmap(per_block)(xb, mask, gidx)


def residual_update(
    grad: jax.Array,
    u: jax.Array,
    v: jax.Array,
    *,
    momentum: float,
    nesterov: bool,
) -> tuple[jax.Array, jax.Array]:
    """Fused momentum-correction + residual accumulation (Alg 4 l.11–19)."""
    g = grad.astype(jnp.float32)
    u_new = momentum * u + g
    v_new = v + u_new
    if nesterov:
        v_new = v_new + g
    return u_new, v_new
