"""Pallas TPU kernel: fused momentum correction + residual accumulation.

Alg 4 lines 11–19 touch three param-sized f32 buffers (g, U, V) back to back;
unfused that is 5 HBM reads + 2 writes. The fusion does one read of each and
one write of each per VMEM block — the memory-bound hot loop RedSync's Fig 10
labels ``mask``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, u_ref, v_ref, u_out, v_out, *, momentum: float,
            nesterov: bool):
    g = g_ref[...].astype(jnp.float32)
    u_new = momentum * u_ref[...] + g
    v_new = v_ref[...] + u_new
    if nesterov:
        v_new = v_new + g
    u_out[...] = u_new
    v_out[...] = v_new


def residual_update(
    grad2d: jax.Array,
    u2d: jax.Array,
    v2d: jax.Array,
    *,
    momentum: float,
    nesterov: bool,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """All inputs [nb, block] f32 (grad may be bf16). Returns (U', V')."""
    nb, block = grad2d.shape
    kern = functools.partial(_kernel, momentum=momentum, nesterov=nesterov)
    spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
        ],
        interpret=interpret,
    )(grad2d, u2d, v2d)
