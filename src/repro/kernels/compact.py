"""Pallas TPU kernel: block-bucketed stream compaction (DESIGN.md §2).

GPU RedSync compacts survivors (|x| > t) with a device-wide prefix sum +
scattered writes. TPU has neither warp scatter nor cheap global prefix sums,
so we restructure:

  * each VMEM block packs its own survivors to the front of a PRIVATE
    ``cap_per_block`` bucket — no cross-block carry at all;
  * within the block, target slots come from an inclusive ``cumsum`` over the
    survivor mask (VPU), and the pack itself is a **one-hot matmul on the
    MXU**: ``out[c] = Σ_b x[b]·onehot[b,c]`` — scatter re-expressed as GEMM;
  * per-block survivor counts are emitted so the caller can (a) detect bucket
    overflow and (b) compute the global nnz with one small reduction.

The resulting [nb, cap] buckets are a short array that the caller top-k's or
filters exactly (Alg 2's "top-k on the trimmed remainder"), at ~D·n cost.

Indices are packed with an i32 where-reduce on the VPU rather than the MXU
matmul: f32 mantissas (2^24) cannot hold indices of multi-hundred-MB shards.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(thr_ref, x_ref, vals_ref, idx_ref, cnt_ref, *, block: int,
            cap: int, total: int):
    i = pl.program_id(0)
    x = x_ref[...].reshape(block).astype(jnp.float32)
    gidx = i * block + jax.lax.iota(jnp.int32, block)
    mask = (jnp.abs(x) > thr_ref[0, 0]) & (gidx < total)

    cnt_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))

    # target slot per survivor (0-based), overflow beyond cap is dropped
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    live = mask & (pos < cap)
    # one-hot pack: [block, cap]; values go through an MXU-friendly matmul,
    # indices through an exact i32 where-reduce.
    onehot = (pos[:, None] == jax.lax.iota(jnp.int32, cap)[None, :]) & live[:, None]
    vals_ref[...] = (x[:, None] * onehot.astype(jnp.float32)).sum(0).reshape(1, cap)
    idx_packed = jnp.where(onehot, gidx[:, None], 0).sum(0)
    filled = jnp.sum(onehot.astype(jnp.int32), axis=0) > 0
    idx_ref[...] = jnp.where(filled, idx_packed, total).reshape(1, cap)


def compact_gt(
    x2d: jax.Array,
    threshold: jax.Array,
    cap_per_block: int,
    total: int,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x2d: [nb, block] zero-padded flat residual. Returns
    (values [nb, cap], indices [nb, cap] i32 — padding == total, counts [nb])."""
    nb, block = x2d.shape
    thr = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    kern = functools.partial(_kernel, block=block, cap=cap_per_block,
                             total=total)
    vals, idx, cnt = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cap_per_block), lambda i: (i, 0)),
            pl.BlockSpec((1, cap_per_block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, cap_per_block), jnp.float32),
            jax.ShapeDtypeStruct((nb, cap_per_block), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=interpret,
    )(thr, x2d)
    return vals, idx, cnt[:, 0]
